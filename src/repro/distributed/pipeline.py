"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Alternative layout to the FSDP+TP production mesh (DESIGN.md §5): layers
are partitioned into S contiguous stages; microbatches flow through the
stages with activations handed off by ``lax.ppermute`` under shard_map.

Schedule (GPipe, fill-drain): T = M + S - 1 ticks for M microbatches on
S stages.  At tick t, stage s computes microbatch (t - s) when it is in
range; activations move s -> s+1 between ticks.  Everything is a dense
``lax.fori_loop`` over ticks — stages that would idle in the fill/drain
phase compute on zeros and mask the result, which keeps the step a
static-shape SPMD program (the TPU-native formulation; a dynamic
schedule would retrace).

Bubble fraction = (S - 1) / (M + S - 1) — the classic GPipe overhead,
reported in the §Perf notes.

The module is self-contained (used by its own test + benchmark); the
40-cell dry-run keeps the FSDP+TP layout per DESIGN.md.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def stage_layers(n_layers: int, n_stages: int, stage: int) -> Tuple[int, int]:
    """[lo, hi) layer range of ``stage`` under near-even partitioning."""
    base = n_layers // n_stages
    extra = n_layers % n_stages
    lo = stage * base + min(stage, extra)
    hi = lo + base + (1 if stage < extra else 0)
    return lo, hi


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def make_pipeline_fn(layer_fn: Callable, n_layers: int, n_stages: int,
                     n_micro: int, axis: str = "stage") -> Callable:
    """Build the shard_map body for a GPipe forward pass.

    ``layer_fn(params_for_layer, x) -> x`` applies ONE layer; stacked
    layer params have leading axis ``n_layers`` and are sharded over the
    stage axis OUTSIDE this function (see ``pipeline_forward``).

    Returns ``body(stage_params, x_micro) -> y_micro`` to be wrapped in
    shard_map; ``x_micro``: (M, mb, T, D) microbatched input, sharded
    over stages only virtually (every stage sees the full input but only
    stage 0 consumes it; outputs are emitted by the last stage).
    """
    S, M = n_stages, n_micro

    def body(stage_params, x_micro):
        sid = jax.lax.axis_index(axis)
        mb_shape = x_micro.shape[1:]

        def apply_stage(x):
            def layer_body(i, x):
                p_i = jax.tree_util.tree_map(lambda a: a[i], stage_params)
                return layer_fn(p_i, x)
            n_local = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
            return jax.lax.fori_loop(0, n_local, layer_body, x)

        def tick(t, carry):
            inflight, outputs = carry
            # stage s works on microbatch m = t - s when 0 <= m < M
            m = t - sid
            active = (m >= 0) & (m < M)
            # stage 0 ingests microbatch m from the input; others use the
            # handed-off activation
            x_in = jnp.where(
                sid == 0,
                x_micro[jnp.clip(m, 0, M - 1)],
                inflight)
            y = apply_stage(x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage emits its finished microbatch
            is_last = sid == S - 1
            emit = active & is_last
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, y[None], jnp.clip(m, 0, M - 1), axis=0),
                lambda o: o,
                outputs)
            # hand activations s -> s+1 (ring permute; the wrap-around
            # edge S-1 -> 0 carries zeros which stage 0 ignores)
            inflight = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            return (inflight, outputs)

        inflight0 = jnp.zeros(mb_shape, x_micro.dtype)
        outputs0 = jnp.zeros((M,) + mb_shape, x_micro.dtype)
        _, outputs = jax.lax.fori_loop(0, M + S - 1, tick,
                                       (inflight0, outputs0))
        # only the last stage ever writes into `outputs` (emit masks the
        # rest to zeros), so a psum over the stage axis broadcasts the
        # finished microbatches back to every stage (replicated output)
        return jax.lax.psum(outputs, axis)

    return body


def pipeline_forward(mesh: Mesh, layer_fn: Callable, stacked_params: Any,
                     x: jnp.ndarray, n_micro: int,
                     axis: str = "stage") -> jnp.ndarray:
    """Run a GPipe forward pass of ``n_layers`` stacked layers.

    stacked_params: pytree with leading layer axis L (sharded over
    ``axis``); x: (B, T, D) with B % n_micro == 0.
    """
    from jax.experimental.shard_map import shard_map

    S = mesh.shape[axis]
    L = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    assert L % S == 0, (L, S)
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    x_micro = x.reshape((n_micro, mb) + x.shape[1:])

    body = make_pipeline_fn(layer_fn, L, S, n_micro, axis)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),       # params split by stage; x replicated
        out_specs=P(),                 # replicated output
        check_rep=False)
    y_micro = fn(stacked_params, x_micro)
    return y_micro.reshape((B,) + x.shape[1:])
