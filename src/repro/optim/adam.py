"""AdamW over parameter pytrees (our own, no optax dependency).

Moments are stored in fp32 regardless of param dtype; under FSDP the
moment trees inherit the parameter PartitionSpecs so optimizer state is
fully sharded (ZeRO-2 equivalent).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray          # ()
    m: Any                     # like params (fp32)
    v: Any


def init_adam(params: Any) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree_util.tree_map(zeros, params),
                     v=jax.tree_util.tree_map(zeros, params))


def adam_update(grads: Any, state: AdamState, params: Any, *,
                lr: float | jnp.ndarray = 1e-4, b1: float = 0.9,
                b2: float = 0.95, eps: float = 1e-8,
                weight_decay: float = 0.0,
                grad_clip: Optional[float] = 1.0
                ) -> Tuple[Any, AdamState, Dict[str, jnp.ndarray]]:
    step = state.step + 1

    gnorm = global_norm(grads)
    if grad_clip is not None:
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
    else:
        scale = jnp.float32(1.0)
    # production NaN-guard: a single non-finite gradient (hardware fault,
    # overflow batch) must not poison the moments — skip the update.
    # NB: this must ZERO the gradients, not scale them (NaN * 0 == NaN).
    ok = jnp.isfinite(gnorm)
    scale = jnp.where(ok, scale, 0.0)
    grads = jax.tree_util.tree_map(
        lambda g: jnp.where(ok, g, jnp.zeros_like(g)), grads)

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    # clip scale fused into the moment updates: avoids materialising a
    # scaled copy of the full gradient tree (a full-model fp32 buffer)
    new_m = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * (g.astype(jnp.float32) * scale),
        state.m, grads)
    new_v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(
            g.astype(jnp.float32) * scale), state.v, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, new_m, new_v)
    return new_params, AdamState(step, new_m, new_v), {"grad_norm": gnorm}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
