"""int8 gradient compression with error feedback for the cross-pod
all-reduce (DESIGN.md §5 "distributed tricks").

Inside a pod, gradients reduce over the high-bandwidth ICI ``data`` axis
in full precision (cheap).  ACROSS pods the links are the scarce resource,
so the pod-level all-reduce quantizes to int8 with a shared scale:

  1. scale = psum-max(|g|) / 127          (tiny scalar collective)
  2. q = round(g / scale)  (int8)         (error e = g - q*scale kept
                                           locally and added next step)
  3. psum(q) over 'pod' in int32, dequantize, divide by n_pods.

8x less cross-pod traffic than fp32 (4x vs bf16); error feedback makes
the quantization noise telescoping rather than accumulating.

``compressed_psum_tree`` is written for use inside shard_map with a
``pod`` axis; the pure function ``quantize_roundtrip`` backs the unit
tests and the error-feedback property test.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def quantize_roundtrip(x: jnp.ndarray,
                       err: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Local quantize/dequantize with error feedback (no collective)."""
    x = x.astype(jnp.float32)
    if err is not None:
        x = x + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    deq = dequantize(quantize(x, scale), scale)
    return deq, x - deq


def compressed_psum(x: jnp.ndarray, axis: str,
                    err: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-reduce-mean ``x`` over ``axis`` in int8.  Returns (mean, err)."""
    x = x.astype(jnp.float32)
    if err is not None:
        x = x + err
    n = jax.lax.psum(1, axis)
    # shared scale so the integer sum is well-defined
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = quantize(x, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    mean = dequantize(total, scale) / n
    # local error vs what this shard contributed
    err_new = x - dequantize(q, scale)
    return mean, err_new


def compressed_psum_tree(tree: Any, axis: str, err_tree: Optional[Any] = None
                         ) -> Tuple[Any, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    errs = (jax.tree_util.tree_leaves(err_tree) if err_tree is not None
            else [None] * len(leaves))
    outs, new_errs = [], []
    for l, e in zip(leaves, errs):
        o, ne = compressed_psum(l, axis, e)
        outs.append(o)
        new_errs.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, new_errs))
