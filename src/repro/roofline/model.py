"""Roofline terms for TPU v5e (assignment constants).

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s HBM)
  collective term = collective_bytes / (chips x ~50 GB/s per ICI link)

cost_analysis() on the post-SPMD module reports PER-DEVICE flops/bytes, so
the per-chip division is already done; we scale back up for the recorded
totals.  MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) measures how
much of compiled compute is useful.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import ShapeSpec
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (DESIGN.md; 1 link assumed)


def roofline_terms(*, flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float,
                   n_chips: int) -> Dict:
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = bytes_per_device / HBM_BW
    t_collective = collective_bytes_per_device / ICI_BW
    terms = {"t_compute": t_compute, "t_memory": t_memory,
             "t_collective": t_collective}
    bound = max(terms, key=terms.get).replace("t_", "")
    t_crit = max(t_compute, t_memory, t_collective)
    return {
        **terms,
        "bound": bound,
        "t_critical": t_crit,
        "compute_fraction": t_compute / t_crit if t_crit else 0.0,
        "total_flops": flops_per_device * n_chips,
        "total_bytes": bytes_per_device * n_chips,
        "total_collective_bytes": collective_bytes_per_device * n_chips,
    }


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6*N*D useful-FLOPs estimate for the cell's workload."""
    n = cfg.active_param_count() if cfg.moe is not None else \
        cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq
