"""Parse collective traffic out of post-SPMD optimized HLO text.

cost_analysis() does not report collective bytes, so we sum operand/result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op and convert to per-device link traffic with the
standard ring-algorithm factors:

  all-reduce       2 * S * (g-1)/g      (reduce-scatter + all-gather)
  all-gather       R * (g-1)/g          (R = full result size)
  reduce-scatter   S * (g-1)/g          (S = full operand size)
  all-to-all       S * (g-1)/g
  collective-permute  S                 (point-to-point)
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def collective_bytes(hlo_text: str) -> Dict:
    """Per-device collective traffic summed over the module."""
    per_op = defaultdict(float)
    counts = defaultdict(int)
    total = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        g = _group_size(line)
        lhs, _, rhs = line.partition("=")
        result_b = _shape_bytes(lhs)
        # operand bytes: shapes appearing in the call args
        operand_b = _shape_bytes(rhs.split("(", 1)[-1])
        frac = (g - 1) / g
        if op == "all-reduce":
            moved = 2.0 * operand_b * frac
        elif op == "all-gather":
            moved = result_b * frac
        elif op == "reduce-scatter":
            moved = operand_b * frac
        elif op == "all-to-all":
            moved = operand_b * frac
        else:                            # collective-permute
            moved = operand_b
        per_op[op] += moved
        counts[op] += 1
        total += moved
    return {
        "bytes_per_device": total,
        "by_op_bytes": dict(per_op),
        "op_counts": dict(counts),
    }
