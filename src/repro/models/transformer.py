"""Decoder-only LM assembly for the dense / moe / vlm families.

Layers are stacked along a leading axis and executed with
``jax.lax.scan`` so the compiled HLO contains ONE layer body regardless
of depth — essential to keep 512-device dry-run compiles tractable.

Entry points:
  init_lm_params / forward_hidden (training) / prefill / decode_step
  run_blocks — scan over an arbitrary [start, end) layer slice (used by
  the mixed-resolution restoration logic, which splits the backbone at
  the restoration point).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ParallelCtx:
    """Threading of mesh/axis info through model code.  None mesh = local."""
    mesh: Any = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    use_ep: bool = True
    remat: bool = False
    sp: bool = False      # sequence parallelism: shard the layer-carry
                          # hidden state's d_model over the model axis

    def constrain(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*spec)))

    def hidden(self, x):
        """Constraint for (B, T, D) residual-stream activations."""
        if self.mesh is None:
            return x
        last = self.model_axis if (
            self.sp and x.shape[-1] % self.mesh.shape[self.model_axis] == 0
        ) else None
        return self.constrain(x, self.data_axes, None, last)


LOCAL = ParallelCtx()


# ---------------------------------------------------------------------------
# single block


def _layer_kind(cfg: ModelConfig, idx: int) -> str:
    if cfg.moe is not None and idx >= cfg.moe.first_dense_layers:
        return "moe"
    return "dense"


def init_block(cfg: ModelConfig, key, dtype, kind: str):
    ks = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg, dtype), "ln2": L.init_norm(cfg, dtype)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(cfg, ks[0], dtype)
    else:
        p["attn"] = attn.init_attention(cfg, ks[0], dtype)
    if kind == "moe":
        p["ffn"] = moe_lib.init_moe(cfg, ks[1], dtype)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.d_ff_dense:
            d_ff = cfg.moe.d_ff_dense
        p["ffn"] = L.init_mlp(cfg, ks[1], dtype, d_ff=d_ff)
    return p


def block_forward(cfg: ModelConfig, p, x, positions, ctx: ParallelCtx,
                  kind: str, cache=None, pos=None):
    """Pre-norm block.  cache/pos semantics follow attention.py.
    Returns (x, new_cache, aux_loss)."""
    h = L.apply_norm(cfg, p["ln1"], x)
    new_cache = None
    if cfg.mla is not None:
        if cache is not None:
            a, new_cache = attn.mla_forward(cfg, p["attn"], h, positions,
                                            cache=cache, pos=pos)
        else:
            a = attn.mla_forward(cfg, p["attn"], h, positions)
    else:
        if cache is None:
            a = attn.attention_forward(cfg, p["attn"], h, positions)
        elif pos is None:
            a, new_cache = attn.attention_prefill(cfg, p["attn"], h,
                                                  positions, cache)
        else:
            a, new_cache = attn.attention_decode(cfg, p["attn"], h, pos, cache)
    x = x + a
    x = ctx.hidden(x)

    h = L.apply_norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        if ctx.mesh is not None and ctx.use_ep:
            f, aux = moe_lib.moe_sharded(cfg, p["ffn"], h, ctx.mesh,
                                         data_axes=ctx.data_axes,
                                         model_axis=ctx.model_axis)
        else:
            f, aux = moe_lib.moe_local(cfg, p["ffn"], h)
    else:
        f = L.apply_mlp(cfg, p["ffn"], h)
    x = x + f
    x = ctx.hidden(x)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# parameter assembly


def _stack_init(init_fn, key, n: int):
    """vmap an init over n layer keys -> params stacked on axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_lm_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {"embed": L.init_embedding(cfg, ks[0], dtype)}

    n_dense = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    if cfg.moe is None:
        n_dense = cfg.n_layers
    n_moe = cfg.n_layers - n_dense
    if n_dense:
        params["dense_blocks"] = _stack_init(
            lambda k: init_block(cfg, k, dtype, "dense"), ks[1], n_dense)
    if n_moe:
        params["moe_blocks"] = _stack_init(
            lambda k: init_block(cfg, k, dtype, "moe"), ks[2], n_moe)

    params["final_norm"] = L.init_norm(cfg, dtype)
    params["lm_head"] = L.init_lm_head(cfg, ks[3], dtype)

    if cfg.vlm is not None:
        pks = jax.random.split(ks[4], 2)
        params["projector"] = {
            "w1": L.dense_init(pks[0], (cfg.vlm.vision_hidden, cfg.d_model),
                               dtype),
            "b1": jnp.zeros((cfg.d_model,), dtype),
            "w2": L.dense_init(pks[1], (cfg.d_model, cfg.d_model), dtype),
            "b2": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


def _block_stacks(cfg: ModelConfig, params):
    """Ordered [(kind, stacked_params, n_layers)] covering the backbone."""
    out = []
    if "dense_blocks" in params:
        n = jax.tree_util.tree_leaves(params["dense_blocks"])[0].shape[0]
        out.append(("dense", params["dense_blocks"], n))
    if "moe_blocks" in params:
        n = jax.tree_util.tree_leaves(params["moe_blocks"])[0].shape[0]
        out.append(("moe", params["moe_blocks"], n))
    return out


# ---------------------------------------------------------------------------
# scanned execution


def _scan_blocks(cfg, stack, kind, x, positions, ctx, caches=None, pos=None):
    """Scan a homogeneous stack of blocks.  caches: stacked (L, ...) pytree."""

    def body(carry, layer_in):
        x, aux = carry
        p, cache = layer_in
        x, new_cache, a = block_forward(cfg, p, x, positions, ctx, kind,
                                        cache=cache, pos=pos)
        return (x, aux + a), new_cache

    body_fn = jax.checkpoint(body) if ctx.remat else body
    (x, aux), new_caches = L.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), (stack, caches))
    return x, aux, new_caches


def embed_inputs(cfg: ModelConfig, params, tokens,
                 image_embeds: Optional[jnp.ndarray] = None):
    x = L.embed_tokens(params["embed"], tokens)
    if cfg.vlm is not None and image_embeds is not None:
        pr = params["projector"]
        v = jax.nn.gelu(image_embeds @ pr["w1"] + pr["b1"]) @ pr["w2"] + pr["b2"]
        x = jnp.concatenate([v.astype(x.dtype), x], axis=1)
    return x


def forward_hidden(cfg: ModelConfig, params, tokens, ctx: ParallelCtx = LOCAL,
                   image_embeds=None):
    """Training/eval forward: final hidden states + aux loss."""
    x = embed_inputs(cfg, params, tokens, image_embeds)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    x = ctx.hidden(x)
    aux_total = jnp.zeros((), jnp.float32)
    for kind, stack, n in _block_stacks(cfg, params):
        x, aux, _ = _scan_blocks(cfg, stack, kind, x, positions, ctx)
        aux_total = aux_total + aux
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, aux_total


def logits_from_hidden(cfg: ModelConfig, params, x, ctx: ParallelCtx = LOCAL):
    logits = L.lm_logits(cfg, params["lm_head"], params["embed"], x)
    return ctx.constrain(logits, ctx.data_axes, None, ctx.model_axis)


# ---------------------------------------------------------------------------
# serving: prefill + decode


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Stacked (L, ...) caches per homogeneous block stack."""
    def one(n):
        if cfg.mla is not None:
            c = attn.init_mla_cache(cfg, batch, max_len, dtype)
        else:
            c = attn.init_kv_cache(cfg, batch, max_len, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c)

    n_dense = cfg.moe.first_dense_layers if cfg.moe is not None else cfg.n_layers
    n_dense = min(n_dense, cfg.n_layers)
    n_moe = cfg.n_layers - n_dense
    caches = {}
    if n_dense:
        caches["dense_blocks"] = one(n_dense)
    if n_moe:
        caches["moe_blocks"] = one(n_moe)
    return caches


def prefill(cfg: ModelConfig, params, tokens, caches,
            ctx: ParallelCtx = LOCAL, image_embeds=None):
    """Prefill the KV caches; returns (last_hidden, caches, aux)."""
    x = embed_inputs(cfg, params, tokens, image_embeds)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    x = ctx.hidden(x)
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for kind, stack, n in _block_stacks(cfg, params):
        name = f"{kind}_blocks"
        x, aux, cs = _scan_blocks(cfg, stack, kind, x, positions, ctx,
                                  caches=caches[name])
        new_caches[name] = cs
        aux_total = aux_total + aux
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, new_caches, aux_total


def decode_step(cfg: ModelConfig, params, token, pos, caches,
                ctx: ParallelCtx = LOCAL):
    """One decode step.  token: (B, 1) int32; pos: scalar int32.
    Returns (logits (B, 1, V), caches)."""
    x = L.embed_tokens(params["embed"], token)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos)
    x = ctx.hidden(x)
    new_caches = {}
    for kind, stack, n in _block_stacks(cfg, params):
        name = f"{kind}_blocks"
        x, _, cs = _scan_blocks(cfg, stack, kind, x, positions, ctx,
                                caches=caches[name], pos=pos)
        new_caches[name] = cs
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params, x, ctx)
    return logits, new_caches


# ---------------------------------------------------------------------------
# layer-slice execution (mixed-resolution restoration splits the backbone)


def slice_stack(stack, s: int, e: int):
    return jax.tree_util.tree_map(lambda a: a[s:e], stack)


def run_blocks(cfg: ModelConfig, params, x, positions, start: int, end: int,
               ctx: ParallelCtx = LOCAL, caches=None, pos=None):
    """Run backbone layers [start, end) on hidden states x.

    Handles stacks spanning the dense/moe boundary.  caches, when given,
    must be the full stacked cache pytree; the slice is updated in place
    (functionally).  Returns (x, caches, aux).
    """
    offset = 0
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = dict(caches) if caches is not None else None
    for kind, stack, n in _block_stacks(cfg, params):
        name = f"{kind}_blocks"
        s = max(start - offset, 0)
        e = min(end - offset, n)
        if s < e:
            sub = slice_stack(stack, s, e)
            sub_cache = (slice_stack(caches[name], s, e)
                         if caches is not None else None)
            x, aux, cs = _scan_blocks(cfg, sub, kind, x, positions, ctx,
                                      caches=sub_cache, pos=pos)
            aux_total = aux_total + aux
            if caches is not None:
                new_caches[name] = jax.tree_util.tree_map(
                    lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                        full, part.astype(full.dtype), s, axis=0),
                    new_caches[name], cs)
        offset += n
    return x, new_caches, aux_total
