"""Shared building blocks: norms, RoPE, MLPs, embeddings.

Everything is a pure function over explicit parameter pytrees (nested
dicts of jnp arrays).  Initializers return (params) and the forward
functions take (params, x, ...).  No framework dependency.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.quant import qtensor as qt

# ---------------------------------------------------------------------------
# scan with a global unroll switch (cost-probe mode)
#
# XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
# count, so FLOP/byte/collective numbers read from a compiled scanned
# model are wrong by ~the trip count.  launch/costing.py lowers tiny
# fully-unrolled probe configs and extrapolates; it flips this flag so
# every model/trainer scan unrolls (normal runs keep rolled scans — that
# is what makes compile times tractable at depth).

SCAN_UNROLL = False


def scan(body, init, xs, length=None):
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if SCAN_UNROLL else 1)


# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LM inits)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * weight.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dt)


def init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), dtype),
                "b": jnp.zeros((cfg.d_model,), dtype)}
    return {"w": jnp.ones((cfg.d_model,), dtype)}


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p.get("b"), cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# rotary embeddings


def rope_frequencies(head_dim: int, theta: float,
                     partial_factor: float = 1.0) -> jnp.ndarray:
    """Inverse frequencies for the rotated sub-dimension."""
    rot_dim = int(head_dim * partial_factor) // 2 * 2
    exponent = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / max(rot_dim, 1)
    return 1.0 / (theta ** exponent)          # (rot_dim // 2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               partial_factor: float = 1.0) -> jnp.ndarray:
    """Rotate the leading ``partial_factor`` fraction of the head dim.

    x: (..., T, H, Dh); positions: broadcastable to (..., T).
    """
    head_dim = x.shape[-1]
    rot_dim = int(head_dim * partial_factor) // 2 * 2
    if rot_dim == 0:
        return x
    inv_freq = rope_frequencies(head_dim, theta, partial_factor)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (...,T,rot/2)
    cos = jnp.cos(ang)[..., None, :]    # (..., T, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_positions(n_pos: int, dim: int) -> jnp.ndarray:
    """Whisper-style sinusoidal positional embeddings."""
    inv = jnp.exp(-jnp.arange(dim // 2) * (math.log(10000.0) / (dim // 2 - 1)))
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs


def init_mlp(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "silu":          # SwiGLU: gate + up + down
        return {
            "w_gate": dense_init(ks[0], (cfg.d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (cfg.d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, cfg.d_model), dtype),
        }
    return {                               # plain GELU MLP (whisper)
        "w_up": dense_init(ks[0], (cfg.d_model, d_ff), dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(ks[1], (d_ff, cfg.d_model), dtype),
        "b_down": jnp.zeros((cfg.d_model,), dtype),
    }


def apply_mlp(cfg: ModelConfig, p, x):
    if "w_gate" in p:
        h = jax.nn.silu(qt.matmul(x, p["w_gate"])) * qt.matmul(x, p["w_up"])
        return qt.matmul(h, p["w_down"])
    h = jax.nn.gelu(qt.matmul(x, p["w_up"]) + p["b_up"], approximate=True)
    return qt.matmul(h, p["w_down"]) + p["b_down"]


# ---------------------------------------------------------------------------
# embedding / unembedding


def init_embedding(cfg: ModelConfig, key, dtype):
    p = {"tok": embed_init(key, (cfg.vocab_size, cfg.d_model), dtype)}
    return p


def embed_tokens(p, tokens):
    return p["tok"][tokens]


def init_lm_head(cfg: ModelConfig, key, dtype):
    if cfg.tied_embeddings:
        return {}
    return {"w": dense_init(key, (cfg.d_model, cfg.vocab_size), dtype)}


def lm_logits(cfg: ModelConfig, head_p, embed_p, x):
    if cfg.tied_embeddings:
        return x @ embed_p["tok"].T
    return x @ head_p["w"]
