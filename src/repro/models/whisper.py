"""Whisper-medium style encoder–decoder backbone.

Per the assignment the conv/mel frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, T_enc, d_model).  Sinusoidal
positions are added to encoder frames; the decoder uses learned
positions, causal self-attention with a KV cache, and cross-attention
whose K/V are computed once from the encoder output at prefill.
LayerNorm + GELU (not RMS/SwiGLU) to stay faithful to the family.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import ParallelCtx, LOCAL


def _enc_dec_counts(cfg: ModelConfig):
    return cfg.encdec.n_encoder_layers, cfg.n_layers


def init_whisper_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    n_enc, n_dec = _enc_dec_counts(cfg)
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        kk = jax.random.split(k, 2)
        return {
            "ln1": L.init_norm(cfg, dtype),
            "attn": attn.init_attention(cfg, kk[0], dtype),
            "ln2": L.init_norm(cfg, dtype),
            "ffn": L.init_mlp(cfg, kk[1], dtype),
        }

    def dec_layer(k):
        kk = jax.random.split(k, 3)
        return {
            "ln1": L.init_norm(cfg, dtype),
            "self_attn": attn.init_attention(cfg, kk[0], dtype),
            "ln_x": L.init_norm(cfg, dtype),
            "cross_attn": attn.init_cross_attention(cfg, kk[1], dtype),
            "ln2": L.init_norm(cfg, dtype),
            "ffn": L.init_mlp(cfg, kk[2], dtype),
        }

    return {
        "enc_blocks": jax.vmap(enc_layer)(jax.random.split(ks[0], n_enc)),
        "enc_norm": L.init_norm(cfg, dtype),
        "embed": L.init_embedding(cfg, ks[1], dtype),
        "dec_pos": L.embed_init(ks[2], (cfg.max_seq_len, cfg.d_model), dtype),
        "dec_blocks": jax.vmap(dec_layer)(jax.random.split(ks[3], n_dec)),
        "final_norm": L.init_norm(cfg, dtype),
        "lm_head": L.init_lm_head(cfg, ks[4], dtype),
    }


def encode(cfg: ModelConfig, params, frames, ctx: ParallelCtx = LOCAL):
    """frames: (B, T_enc, d_model) stub embeddings -> encoder output."""
    B, T, D = frames.shape
    pos = L.sinusoidal_positions(T, D).astype(frames.dtype)
    x = frames + pos[None]
    x = ctx.hidden(x)
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))

    def body(x, p):
        h = L.apply_norm(cfg, p["ln1"], x)
        x = x + attn.attention_forward(cfg, p["attn"], h, positions,
                                       causal=False, rope=False)
        x = x + L.apply_mlp(cfg, p["ffn"], L.apply_norm(cfg, p["ln2"], x))
        x = ctx.hidden(x)
        return x, None

    body_fn = jax.checkpoint(body) if ctx.remat else body
    x, _ = L.scan(body_fn, x, params["enc_blocks"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def _dec_block(cfg, p, x, positions, enc_out, cache=None, pos=None):
    h = L.apply_norm(cfg, p["ln1"], x)
    if cache is None:
        a = attn.attention_forward(cfg, p["self_attn"], h, positions,
                                   rope=False)
        new_cache = None
    elif pos is None:
        a, new_cache = attn.attention_prefill(cfg, p["self_attn"], h,
                                              positions, cache, rope=False)
    else:
        a, new_cache = attn.attention_decode(cfg, p["self_attn"], h, pos,
                                             cache, rope=False)
    x = x + a
    x = x + attn.cross_attention(cfg, p["cross_attn"],
                                 L.apply_norm(cfg, p["ln_x"], x), enc_out)
    x = x + L.apply_mlp(cfg, p["ffn"], L.apply_norm(cfg, p["ln2"], x))
    return x, new_cache


def decode_train(cfg: ModelConfig, params, tokens, frames,
                 ctx: ParallelCtx = LOCAL):
    """Teacher-forced decoder over full target sequence."""
    enc_out = encode(cfg, params, frames, ctx)
    B, T = tokens.shape
    x = L.embed_tokens(params["embed"], tokens) + params["dec_pos"][None, :T]
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))

    def body(x, p):
        x, _ = _dec_block(cfg, p, x, positions, enc_out)
        x = ctx.hidden(x)
        return x, None

    body_fn = jax.checkpoint(body) if ctx.remat else body
    x, _ = L.scan(body_fn, x, params["dec_blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, jnp.zeros((), jnp.float32)


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int, dtype):
    c = attn.init_kv_cache(cfg, batch, max_len, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), c)


def prefill(cfg: ModelConfig, params, tokens, frames, caches,
            ctx: ParallelCtx = LOCAL):
    """Encode frames + teacher-forced prefill of decoder self-attn caches.
    Returns (hidden, (enc_out, caches), aux)."""
    enc_out = encode(cfg, params, frames, ctx)
    B, T = tokens.shape
    x = L.embed_tokens(params["embed"], tokens) + params["dec_pos"][None, :T]
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))

    def body(x, inp):
        p, c = inp
        x, c2 = _dec_block(cfg, p, x, positions, enc_out, cache=c)
        return x, c2

    x, new_caches = L.scan(body, x, (params["dec_blocks"], caches))
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, (enc_out, new_caches), jnp.zeros((), jnp.float32)


def decode_step(cfg: ModelConfig, params, token, pos, state,
                ctx: ParallelCtx = LOCAL):
    """One decoder token.  state = (enc_out, caches)."""
    enc_out, caches = state
    x = L.embed_tokens(params["embed"], token) + \
        params["dec_pos"][pos][None, None, :]
    B = x.shape[0]
    positions = jnp.full((B, 1), pos)

    def body(x, inp):
        p, c = inp
        x, c2 = _dec_block(cfg, p, x, positions, enc_out, cache=c, pos=pos)
        return x, c2

    x, new_caches = L.scan(body, x, (params["dec_blocks"], caches))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["lm_head"], params["embed"], x)
    return logits, (enc_out, new_caches)
