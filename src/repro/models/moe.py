"""Mixture-of-Experts FFN (DBRX, DeepSeek-V2 style).

Expert parallelism strategy (TPU-native, see DESIGN.md §5):
activations arrive replicated over the ``model`` mesh axis (standard
Megatron TP layout), expert weights are sharded over ``model`` on the
expert axis.  Each model rank locally gathers the tokens routed to *its*
experts (no dispatch all-to-all needed — the token buffer is already
resident), computes them, scatter-adds partial outputs, and a single
``psum`` over ``model`` combines — the same collective a dense TP FFN
would need.  Dispatch uses static capacity buffers so serving/training
graphs never retrace.

Two entry points share the inner math:
  * ``moe_local``   — single-device (smoke tests, CPU benchmarks)
  * ``moe_sharded`` — shard_map over the model axis (EP)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import dense_init


# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 5)
    E, D, F = m.n_experts, cfg.d_model, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], (D, E), dtype, scale=0.02),
        "w_gate": dense_init(ks[1], (E, D, F), dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype),
    }
    if m.n_shared_experts > 0:
        Fs = m.d_ff_expert * m.n_shared_experts
        sks = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sks[0], (D, Fs), dtype),
            "w_up": dense_init(sks[1], (D, Fs), dtype),
            "w_down": dense_init(sks[2], (Fs, D), dtype),
        }
    return p


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    cap = int(math.ceil(m.top_k * n_tokens / m.n_experts * m.capacity_factor))
    return max(8, -(-cap // 8) * 8)      # round up to a multiple of 8


# ---------------------------------------------------------------------------
# inner per-device dispatch/compute (works for full or sharded expert slabs)


def _route(cfg: ModelConfig, router_w, x_flat):
    """Top-k routing.  Returns (top_idx, top_gate, aux_loss)."""
    m = cfg.moe
    logits = (x_flat @ router_w).astype(jnp.float32)        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_gate, top_idx = jax.lax.top_k(probs, m.top_k)        # (N, k)
    top_gate = top_gate / jnp.sum(top_gate, axis=-1, keepdims=True)
    # load-balance aux: E * sum_e( frac_tokens_e * mean_prob_e )
    counts = jnp.sum(jax.nn.one_hot(top_idx, m.n_experts, dtype=jnp.float32),
                     axis=(0, 1))
    frac = counts / (x_flat.shape[0] * m.top_k)
    mean_p = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(frac * mean_p)
    return top_idx, top_gate, aux


def _dispatch_tables(cfg: ModelConfig, top_idx, top_gate, e0: int,
                     n_local: int, capacity: int):
    """Static-capacity dispatch tables for experts [e0, e0+n_local).

    Returns idx_table (E_loc, C) int32 token ids and gate_table (E_loc, C)
    f32 gates (0 for padding slots).
    """
    m = cfg.moe
    N = top_idx.shape[0]
    flat_e = top_idx.reshape(-1)                         # (N*k,)
    flat_g = top_gate.reshape(-1)
    tok_of = jnp.arange(N * m.top_k, dtype=jnp.int32) // m.top_k
    local_e = flat_e - e0                                # (N*k,)
    is_local = (local_e >= 0) & (local_e < n_local)
    # position within each local expert, computed on a (N*k, E_loc) one-hot
    onehot = (local_e[:, None] == jnp.arange(n_local)[None, :]) & \
        is_local[:, None]                                # (N*k, E_loc)
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    slot = jnp.sum(jnp.where(onehot, pos, 0), axis=1)    # (N*k,)
    keep = is_local & (slot < capacity)
    e_ids = jnp.where(keep, local_e, n_local)            # drop row
    s_ids = jnp.where(keep, slot, capacity)
    idx_table = jnp.zeros((n_local, capacity), jnp.int32).at[
        e_ids, s_ids].set(tok_of, mode="drop")
    gate_table = jnp.zeros((n_local, capacity), jnp.float32).at[
        e_ids, s_ids].set(flat_g, mode="drop")
    return idx_table, gate_table


def _expert_ffn(weights, xs):
    """xs: (E_loc, C, D); per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, weights["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xs, weights["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, weights["w_down"])


def _shared_ffn(p_shared, x_flat):
    h = jax.nn.silu(x_flat @ p_shared["w_gate"]) * (x_flat @ p_shared["w_up"])
    return h @ p_shared["w_down"]


def _moe_inner(cfg: ModelConfig, p, x_flat, e0: int, n_local: int,
               capacity: int):
    """Partial MoE output for the local expert slab.  (N, D) partial sum."""
    top_idx, top_gate, aux = _route(cfg, p["router"], x_flat)
    idx_table, gate_table = _dispatch_tables(cfg, top_idx, top_gate,
                                             e0, n_local, capacity)
    xs = x_flat[idx_table]                                    # (E_loc, C, D)
    local_w = {k: p[k] for k in ("w_gate", "w_up", "w_down")}
    ys = _expert_ffn(local_w, xs)
    ys = ys * gate_table[..., None].astype(ys.dtype)
    out = jnp.zeros_like(x_flat).at[idx_table.reshape(-1)].add(
        ys.reshape(-1, x_flat.shape[-1]), mode="drop")
    if "shared" in p:
        out = out + _shared_ffn(p["shared"], x_flat)
    return out, aux


# ---------------------------------------------------------------------------
# public entry points


def moe_local(cfg: ModelConfig, p, x):
    """Single-device MoE: all experts resident."""
    B, S, D = x.shape
    x_flat = x.reshape(B * S, D)
    cap = expert_capacity(cfg, B * S)
    out, aux = _moe_inner(cfg, p, x_flat, 0, cfg.moe.n_experts, cap)
    return out.reshape(B, S, D), aux


def moe_sharded(cfg: ModelConfig, p, x, mesh, *, data_axes=("data",),
                model_axis: str = "model"):
    """Expert-parallel MoE under shard_map.

    x is sharded (batch over data axes, replicated over model); expert
    weights sharded over ``model`` on the expert axis; one psum over
    ``model`` combines partial outputs (same cost as a dense TP FFN
    all-reduce).
    """
    m = cfg.moe
    ep = mesh.shape[model_axis]
    assert m.n_experts % ep == 0, (m.n_experts, ep)
    n_local = m.n_experts // ep
    B, S, D = x.shape

    specs_p = moe_param_specs(cfg, data_axes, model_axis)

    def body(p_loc, x_loc):
        b, s, _ = x_loc.shape
        x_flat = x_loc.reshape(b * s, D)
        cap = expert_capacity(cfg, b * s)
        rank = jax.lax.axis_index(model_axis)
        e0 = rank * n_local
        if "shared" in p_loc:
            # shared expert hidden dim is sharded over model -> contributes
            # a partial product combined by the same psum below.
            pass
        out, aux = _moe_inner(cfg, p_loc, x_flat, e0, n_local, cap)
        out = jax.lax.psum(out, model_axis)
        aux = jax.lax.psum(aux, model_axis) / ep
        return out.reshape(b, s, D), aux

    out, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(specs_p, P(data_axes, None, None)),
        out_specs=(P(data_axes, None, None), P()),
        check_vma=False,
    )(p, x)
    return out, aux


def moe_param_specs(cfg: ModelConfig, data_axes=("data",),
                    model_axis: str = "model"):
    """PartitionSpecs matching init_moe's tree (expert axis over model)."""
    specs = {
        "router": P(None, None),
        "w_gate": P(model_axis, None, None),
        "w_up": P(model_axis, None, None),
        "w_down": P(model_axis, None, None),
    }
    if cfg.moe.n_shared_experts > 0:
        specs["shared"] = {
            "w_gate": P(None, model_axis),
            "w_up": P(None, model_axis),
            "w_down": P(model_axis, None),
        }
    return specs
