"""Zamba2-style hybrid: a Mamba-2 backbone with one SHARED attention
block invoked periodically (weight reuse is the Zamba hallmark).

Implementation: the mamba layers are scanned; inside the scan body a
``lax.cond`` applies the shared transformer block (captured by closure,
not scanned) whenever ``layer_idx % period == period - 1``.  This keeps
the compiled HLO at one mamba body + one shared block regardless of
depth.

Simplification vs. the released checkpoints (noted in DESIGN.md): the
shared block consumes the hidden state directly (no concat-with-embedding
or per-invocation LoRA).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2 as m2
from repro.models.config import ModelConfig
from repro.models.transformer import ParallelCtx, LOCAL

SHARED_PERIOD = 6


def init_hybrid_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)

    def one_mamba(k):
        kk = jax.random.split(k, 2)
        return {"ln": L.init_norm(cfg, dtype),
                "mamba": m2.init_mamba2(cfg, kk[0], dtype)}

    keys = jax.random.split(ks[0], cfg.n_layers)
    params = {
        "embed": L.init_embedding(cfg, ks[1], dtype),
        "mamba_blocks": jax.vmap(one_mamba)(keys),
        "shared": {
            "ln1": L.init_norm(cfg, dtype),
            "attn": attn.init_attention(cfg, ks[2], dtype),
            "ln2": L.init_norm(cfg, dtype),
            "ffn": L.init_mlp(cfg, ks[3], dtype),
        },
        "final_norm": L.init_norm(cfg, dtype),
        "lm_head": L.init_lm_head(cfg, ks[4], dtype),
    }
    return params


def _shared_block(cfg, p, x, positions, cache=None, pos=None):
    h = L.apply_norm(cfg, p["ln1"], x)
    if cache is None:
        a = attn.attention_forward(cfg, p["attn"], h, positions)
        new_cache = None
    elif pos is None:
        a, new_cache = attn.attention_prefill(cfg, p["attn"], h, positions,
                                              cache)
    else:
        a, new_cache = attn.attention_decode(cfg, p["attn"], h, pos, cache)
    x = x + a
    x = x + L.apply_mlp(cfg, p["ffn"], L.apply_norm(cfg, p["ln2"], x))
    return x, new_cache


def n_shared_calls(cfg: ModelConfig) -> int:
    return cfg.n_layers // SHARED_PERIOD


def init_hybrid_caches(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """SSM/conv state per mamba layer + KV cache per shared-attn call."""
    n_attn = max(n_shared_calls(cfg), 1)
    ssm = m2.init_mamba2_state(cfg, batch, dtype)
    ssm = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), ssm)
    kv = attn.init_kv_cache(cfg, batch, max_len, dtype)
    kv = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n_attn,) + a.shape), kv)
    return {"ssm": ssm, "kv": kv}


def forward_hidden(cfg: ModelConfig, params, tokens, ctx: ParallelCtx = LOCAL,
                   image_embeds=None):
    x = L.embed_tokens(params["embed"], tokens)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    x = ctx.hidden(x)
    shared = params["shared"]

    def body(carry, layer_in):
        x, idx = carry
        p = layer_in
        h = L.apply_norm(cfg, p["ln"], x)
        x = x + m2.mamba2_forward(cfg, p["mamba"], h)
        x = jax.lax.cond(
            (idx % SHARED_PERIOD) == SHARED_PERIOD - 1,
            lambda x: _shared_block(cfg, shared, x, positions)[0],
            lambda x: x, x)
        x = ctx.hidden(x)
        return (x, idx + 1), None

    body_fn = jax.checkpoint(body) if ctx.remat else body
    (x, _), _ = L.scan(body_fn, (x, jnp.zeros((), jnp.int32)),
                             params["mamba_blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, jnp.zeros((), jnp.float32)


def prefill(cfg: ModelConfig, params, tokens, caches, ctx: ParallelCtx = LOCAL,
            image_embeds=None):
    """Prefill: mamba states fast-forwarded, shared-attn KV caches filled.

    Shared-attn caches are indexed by call number (layer // period), so
    they are updated inside the scan with a dynamic slice on axis 0.
    """
    x = L.embed_tokens(params["embed"], tokens)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    x = ctx.hidden(x)
    shared = params["shared"]

    def body(carry, layer_in):
        x, idx, kv = carry
        p = layer_in
        h = L.apply_norm(cfg, p["ln"], x)
        # run full-sequence mamba, also emit final ssm/conv state
        x2, ssm_state = _mamba_prefill(cfg, p["mamba"], h)
        x = x + x2

        def with_attn(args):
            x, kv = args
            call = idx // SHARED_PERIOD
            c = jax.tree_util.tree_map(lambda a: a[call % a.shape[0]], kv)
            x, c2 = _shared_block(cfg, shared, x, positions, cache=c)
            kv = jax.tree_util.tree_map(
                lambda full, part: jax.lax.dynamic_update_index_in_dim(
                    full, part.astype(full.dtype), call % full.shape[0], 0),
                kv, c2)
            return x, kv

        x, kv = jax.lax.cond((idx % SHARED_PERIOD) == SHARED_PERIOD - 1,
                             with_attn, lambda a: a, (x, kv))
        x = ctx.hidden(x)
        return (x, idx + 1, kv), ssm_state

    (x, _, kv), ssm_states = L.scan(
        body, (x, jnp.zeros((), jnp.int32), caches["kv"]),
        params["mamba_blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, {"ssm": ssm_states, "kv": kv}, jnp.zeros((), jnp.float32)


def _mamba_prefill(cfg: ModelConfig, p, x):
    """Mamba forward that also returns the end-of-sequence state."""
    s = cfg.ssm
    d_inner, H, conv_ch = m2.ssm_dims(cfg)
    B_, T, D = x.shape
    gN = s.n_groups * s.d_state
    z, xBC, dt_raw = m2._split_proj(cfg, x @ p["w_in"])
    xBC_conv = jax.nn.silu(m2.causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    conv_state = xBC[:, T - (s.d_conv - 1):, :] if T >= s.d_conv - 1 else \
        jnp.pad(xBC, ((0, 0), (s.d_conv - 1 - T, 0), (0, 0)))
    xs, Bm, Cm = jnp.split(xBC_conv, [d_inner, d_inner + gN], axis=-1)
    xs = xs.reshape(B_, T, H, s.head_dim)
    Bm = Bm.reshape(B_, T, s.n_groups, s.d_state)
    Cm = Cm.reshape(B_, T, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    chunk = min(s.chunk_size, T)
    y, final_state = m2.ssd_chunked(xs, dt, A, Bm, Cm, chunk,
                                    return_final_state=True)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, T, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["w_out"], {"conv": conv_state.astype(x.dtype),
                            "ssm": final_state}


def decode_step(cfg: ModelConfig, params, token, pos, caches,
                ctx: ParallelCtx = LOCAL):
    x = L.embed_tokens(params["embed"], token)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos)
    shared = params["shared"]

    def body(carry, layer_in):
        x, idx, kv = carry
        p, state = layer_in
        h = L.apply_norm(cfg, p["ln"], x)
        dx, new_state = m2.mamba2_decode(cfg, p["mamba"], h, state)
        x = x + dx

        def with_attn(args):
            x, kv = args
            call = idx // SHARED_PERIOD
            c = jax.tree_util.tree_map(lambda a: a[call % a.shape[0]], kv)
            x, c2 = _shared_block(cfg, shared, x, positions, cache=c, pos=pos)
            kv = jax.tree_util.tree_map(
                lambda full, part: jax.lax.dynamic_update_index_in_dim(
                    full, part.astype(full.dtype), call % full.shape[0], 0),
                kv, c2)
            return x, kv

        x, kv = jax.lax.cond((idx % SHARED_PERIOD) == SHARED_PERIOD - 1,
                             with_attn, lambda a: a, (x, kv))
        return (x, idx + 1, kv), new_state

    (x, _, kv), ssm_states = L.scan(
        body, (x, jnp.zeros((), jnp.int32), caches["kv"]),
        (params["mamba_blocks"], caches["ssm"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["lm_head"], params["embed"], x)
    return logits, {"ssm": ssm_states, "kv": kv}
