"""Pure Mamba-2 LM (mamba2-370m): scanned mamba blocks, no attention."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as m2
from repro.models.config import ModelConfig
from repro.models.hybrid import _mamba_prefill
from repro.models.transformer import ParallelCtx, LOCAL


def init_ssm_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)

    def one(k):
        return {"ln": L.init_norm(cfg, dtype),
                "mamba": m2.init_mamba2(cfg, k, dtype)}

    return {
        "embed": L.init_embedding(cfg, ks[0], dtype),
        "mamba_blocks": jax.vmap(one)(jax.random.split(ks[1], cfg.n_layers)),
        "final_norm": L.init_norm(cfg, dtype),
        "lm_head": L.init_lm_head(cfg, ks[2], dtype),
    }


def forward_hidden(cfg: ModelConfig, params, tokens, ctx: ParallelCtx = LOCAL,
                   image_embeds=None):
    x = L.embed_tokens(params["embed"], tokens)
    x = ctx.hidden(x)

    def body(x, p):
        h = L.apply_norm(cfg, p["ln"], x)
        x = x + m2.mamba2_forward(cfg, p["mamba"], h)
        x = ctx.hidden(x)
        return x, None

    body_fn = jax.checkpoint(body) if ctx.remat else body
    x, _ = L.scan(body_fn, x, params["mamba_blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, jnp.zeros((), jnp.float32)


def init_states(cfg: ModelConfig, batch: int, dtype):
    s = m2.init_mamba2_state(cfg, batch, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), s)


def prefill(cfg: ModelConfig, params, tokens, states,
            ctx: ParallelCtx = LOCAL):
    x = L.embed_tokens(params["embed"], tokens)
    x = ctx.hidden(x)

    def body(x, p):
        h = L.apply_norm(cfg, p["ln"], x)
        dx, state = _mamba_prefill(cfg, p["mamba"], h)
        x = x + dx
        x = ctx.hidden(x)
        return x, state

    x, states = L.scan(body, x, params["mamba_blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, states, jnp.zeros((), jnp.float32)


def decode_step(cfg: ModelConfig, params, token, pos, states,
                ctx: ParallelCtx = LOCAL):
    x = L.embed_tokens(params["embed"], token)

    def body(x, inp):
        p, state = inp
        h = L.apply_norm(cfg, p["ln"], x)
        dx, new_state = m2.mamba2_decode(cfg, p["mamba"], h, state)
        return x + dx, new_state

    x, new_states = L.scan(body, x, (params["mamba_blocks"], states))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["lm_head"], params["embed"], x)
    return logits, new_states
