"""Unified model configuration for the architecture zoo.

One frozen dataclass covers every assigned architecture family:
dense / moe / ssm / hybrid / encdec / vlm plus the paper's own ViT
(vitdet).  Family-specific sub-configs are optional blocks; the registry
dispatches on ``family``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared_experts: int = 0          # deepseek-v2 style always-on experts
    d_ff_expert: int = 0               # per-expert hidden size
    first_dense_layers: int = 0        # leading layers that use a dense FFN
    d_ff_dense: int = 0                # hidden size of those dense FFNs
    capacity_factor: float = 1.25      # dispatch capacity (static shapes)
    router_aux_coef: float = 0.01      # load-balance aux loss weight


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                 # P: SSD head dim
    n_groups: int = 1                  # B/C groups
    chunk_size: int = 256              # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 24
    encoder_seq_len: int = 1500        # whisper: 30 s -> 1500 frames
    frontend: str = "stub"             # modality frontend is a stub per spec


@dataclass(frozen=True)
class VLMConfig:
    n_image_tokens: int = 2880         # anyres: base 576 + 4 tiles * 576
    vision_hidden: int = 1024          # stubbed frontend embedding width
    frontend: str = "stub"


@dataclass(frozen=True)
class MixedResConfig:
    """Paper C1 knobs (2-D ViT native and 1-D sequence adaptation)."""
    enabled: bool = True
    window: int = 8                    # w: window size (patches or tokens)
    downsample: int = 2                # d: per-region downsample factor
    n_subsets: int = 4                 # N: backbone subsets (RP candidates)
    # 1-D adaptation: region span r = window * downsample tokens.


@dataclass(frozen=True)
class ViTConfig:
    """ViTDet-style dense-prediction backbone (the paper's own arch)."""
    img_size: Tuple[int, int] = (1024, 1024)
    patch_size: int = 16
    window_size: int = 8               # fine-tuned 9x9 in paper; 8 = MXU-friendly
    n_subsets: int = 4                 # N subsets; RP after last window block
    out_channels: int = 256            # det-head pyramid width
    n_classes: int = 80


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"   # dense | moe | ssm | hybrid | encdec | vlm | vit
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 256
    vocab_size: int = 1024

    # attention / embedding knobs
    qk_norm: bool = False
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0
    tied_embeddings: bool = False
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    activation: str = "silu"           # silu (SwiGLU) | gelu (plain MLP)
    norm_eps: float = 1e-5
    max_seq_len: int = 131072
    attention_bias: bool = False

    # hybrid layout: e.g. zamba2 — 'm' = mamba block, 'A' = shared attn block
    layer_pattern: Optional[Tuple[str, ...]] = None

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    vit: Optional[ViTConfig] = None
    mixed_res: Optional[MixedResConfig] = None

    # long_500k policy: quadratic-attention archs cannot run 512k decode
    subquadratic: bool = False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        from repro.models.registry import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params_analytic
        return count_params_analytic(self, active_only=True)


def reduced(cfg: ModelConfig, **extra) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        max_seq_len=512,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=64, d_ff_dense=128,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                        chunk_size=32)
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(cfg.encdec, n_encoder_layers=2,
                                           encoder_seq_len=64)
    if cfg.vlm is not None:
        kw["vlm"] = dataclasses.replace(cfg.vlm, n_image_tokens=16,
                                        vision_hidden=32)
    if cfg.vit is not None:
        kw["vit"] = dataclasses.replace(cfg.vit, img_size=(128, 128),
                                        window_size=2, n_subsets=2,
                                        out_channels=32, n_classes=8)
        kw["d_model"] = 64
        kw["n_layers"] = 4                 # 2 subsets of 2 blocks
        if cfg.mixed_res is not None:
            kw["mixed_res"] = dataclasses.replace(cfg.mixed_res, window=2,
                                                  n_subsets=2)
    if cfg.layer_pattern is not None:
        kw["layer_pattern"] = cfg.layer_pattern[:4]
        kw["n_layers"] = len(kw["layer_pattern"])
    kw.update(extra)
    return cfg.replace(**kw)
