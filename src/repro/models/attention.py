"""Attention variants: GQA (full/causal/windowed), KV-cache decode, MLA.

Layouts:  activations (B, T, D);  q/k/v (B, T, H, Dh);  caches are
preallocated to the max sequence length and updated in place with
``dynamic_update_slice`` so serving graphs stay static-shaped.
"""
from __future__ import annotations

import contextlib
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.quant import qtensor as qt

NEG_INF = -2.0 ** 30   # large-finite: avoids NaN rows for fully-masked queries


# ---------------------------------------------------------------------------
# head-importance tap (quant.prune calibration)
#
# When armed, every EAGER attention_forward appends the per-head mean
# |output| (pre-w_o) to the store — the ViT backbone makes exactly
# n_layers attention calls per forward, in layer order, so the store
# reshapes to (frames, layers, heads).  Traced calls never record (the
# tap reads concrete values); the serving/training hot paths see one
# ``is None`` check.

_HEAD_TAP: Optional[List[np.ndarray]] = None


@contextlib.contextmanager
def head_tap(store: List[np.ndarray]):
    """Arm the per-head output-magnitude tap for eager calibration."""
    global _HEAD_TAP
    prev = _HEAD_TAP
    _HEAD_TAP = store
    try:
        yield store
    finally:
        _HEAD_TAP = prev


# ---------------------------------------------------------------------------
# scaled dot-product attention (grouped-query aware, fp32 softmax)


Q_CHUNK = 1024   # flash-style q-block size for long sequences


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
         causal: bool = False,
         q_offset: int | jnp.ndarray = 0,
         kv_len: Optional[jnp.ndarray] = None,
         scale: Optional[float] = None,
         backend: Optional[str] = None) -> jnp.ndarray:
    """q: (B,T,H,Dh)  k/v: (B,S,KV,Dh) with H = KV * G.  Returns (B,T,H,Dh).

    ``q_offset``: absolute position of q[0] (decode: pos; prefill: 0).
    ``kv_len``: optional per-batch valid cache length (B,) for decode.
    ``backend``: kernel backend (kernels.dispatch).  The Pallas lane
    routes two shapes: the plain full-sequence case to the flash kernel,
    and the one-token ``kv_len`` cache read (T == 1) to the decode
    kernel (kernels/decode_attention).  Everything else — nonzero
    ``q_offset``, multi-token ``kv_len`` masks (the padded ViT's
    pre-restoration global blocks), explicit ``scale`` — stays on the
    XLA path.

    Long sequences (T > 2*Q_CHUNK) are processed as a lax.scan over query
    blocks so the live logits buffer is (B, C, H, S) instead of the full
    (B, T, H, S) — the XLA analogue of flash attention's tiling (the
    Pallas kernel in kernels/flash_attention does the same on-chip).
    """
    plain = (kv_len is None and isinstance(q_offset, int) and q_offset == 0
             and scale is None)
    if plain and dispatch.use_pallas(backend):
        return dispatch.flash_attention(q, k, v, causal=causal)
    decode = (kv_len is not None and q.shape[1] == 1 and not causal
              and isinstance(q_offset, int) and q_offset == 0
              and scale is None)
    if decode and dispatch.use_pallas(backend):
        return dispatch.decode_attention(q, k, v, kv_len)
    T = q.shape[1]
    if T > 2 * Q_CHUNK:
        return _sdpa_blocked(q, k, v, causal=causal, q_offset=q_offset,
                             kv_len=kv_len, scale=scale)
    return _sdpa_dense(q, k, v, causal=causal, q_offset=q_offset,
                       kv_len=kv_len, scale=scale)


def _sdpa_blocked(q, k, v, *, causal, q_offset, kv_len, scale):
    B, T0, H, Dh = q.shape
    if T0 % Q_CHUNK:                      # pad q rows; sliced off below
        q = jnp.pad(q, ((0, 0), (0, Q_CHUNK - T0 % Q_CHUNK),
                        (0, 0), (0, 0)))
    T = q.shape[1]
    nb = T // Q_CHUNK
    qb = jnp.moveaxis(q.reshape(B, nb, Q_CHUNK, H, Dh), 1, 0)
    offs = jnp.arange(nb) * Q_CHUNK + q_offset

    def body(_, inp):
        qblk, off = inp
        out = _sdpa_dense(qblk, k, v, causal=causal, q_offset=off,
                          kv_len=kv_len, scale=scale)
        return None, out

    from repro.models.layers import scan as _scan
    _, outs = _scan(body, None, (qb, offs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, T, H, Dh)[:, :T0]


def _sdpa_dense(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                causal: bool = False,
                q_offset: int | jnp.ndarray = 0,
                kv_len: Optional[jnp.ndarray] = None,
                scale: Optional[float] = None) -> jnp.ndarray:
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else Dh ** -0.5
    qg = q.reshape(B, T, KV, G, Dh)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        q_pos = jnp.arange(T) + q_offset
        k_pos = jnp.arange(S)
        mask = q_pos[:, None] >= k_pos[None, :]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    if kv_len is not None:
        valid = jnp.arange(S)[None, :] < kv_len[:, None]          # (B,S)
        logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, T, H, Dh).astype(q.dtype)


def window_sdpa(q, k, v, window: int, *,
                win_valid: Optional[jnp.ndarray] = None,
                backend: Optional[str] = None) -> jnp.ndarray:
    """Non-overlapping local window attention over a 1-D sequence.

    q/k/v: (B, T, H, Dh) with T % window == 0.  Each window attends only
    to itself (ViTDet-style window attention, 1-D layout).  ``backend``
    routes to the Pallas window-attention kernel (kernels.dispatch).

    ``win_valid``: optional (B,) i32 count of VALID windows per sample
    (length-bucketed padded sequences, core.partition.PlanLayout): pad
    windows beyond the count have their outputs zeroed, so padded lanes
    carry deterministic content on both backends.  Window attention is
    window-local, so valid windows are unaffected either way.
    """
    if dispatch.use_pallas(backend):
        return dispatch.window_attention(q, k, v, window,
                                         win_valid=win_valid)
    B, T, H, Dh = q.shape
    W = T // window
    qw = q.reshape(B, W, window, H, Dh).reshape(B * W, window, H, Dh)
    kw = k.reshape(B, W, window, k.shape[2], Dh).reshape(B * W, window, -1, Dh)
    vw = v.reshape(B, W, window, v.shape[2], Dh).reshape(B * W, window, -1, Dh)
    out = sdpa(qw, kw, vw, causal=False)
    out = out.reshape(B, W, window, H, Dh)
    if win_valid is not None:
        keep = jnp.arange(W)[None, :] < win_valid[:, None]       # (B, W)
        out = jnp.where(keep[:, :, None, None, None], out, 0)
    return out.reshape(B, T, H, Dh)


# ---------------------------------------------------------------------------
# standard GQA attention layer


def init_attention(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 6)
    p = {
        "w_q": dense_init(ks[0], (cfg.d_model, cfg.q_dim), dtype),
        "w_k": dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dtype),
        "w_v": dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dtype),
        "w_o": dense_init(ks[3], (cfg.q_dim, cfg.d_model), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    if cfg.attention_bias:
        p["b_q"] = jnp.zeros((cfg.q_dim,), dtype)
        p["b_k"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["b_v"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["b_o"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, p, x, positions, rope: bool = True):
    B, T, _ = x.shape
    if T == 1:
        # decode: the fused-weight concat below copies the whole QKV
        # weight per step, which dominates a single-token GEMV — keep
        # the three small GEMMs here.
        q = qt.matmul(x, p["w_q"])
        k = qt.matmul(x, p["w_k"])
        v = qt.matmul(x, p["w_v"])
        if cfg.attention_bias:
            q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    else:
        # fused QKV: one (D, q_dim + 2*kv_dim) GEMM instead of three —
        # each output column depends only on its own weight column, so
        # the split results are bit-identical to the separate GEMMs
        # (test_backend_dispatch.py asserts this) while the MXU sees
        # one big matmul.  concat_out fuses int8 QuantTensors the same
        # way (per-output-channel scales concatenate with the columns).
        w_qkv = qt.concat_out([p["w_q"], p["w_k"], p["w_v"]])
        qkv = qt.matmul(x, w_qkv)
        if cfg.attention_bias:
            qkv = qkv + jnp.concatenate([p["b_q"], p["b_k"], p["b_v"]])
        q, k, v = jnp.split(qkv, (cfg.q_dim, cfg.q_dim + cfg.kv_dim),
                            axis=-1)
    q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor)
    return q, k, v


def attention_forward(cfg: ModelConfig, p, x, positions, *,
                      causal: bool = True, window: int = 0,
                      rope: bool = True,
                      kv_len: Optional[jnp.ndarray] = None,
                      win_valid: Optional[jnp.ndarray] = None,
                      backend: Optional[str] = None) -> jnp.ndarray:
    """Full-sequence attention (training / prefill without cache reuse).

    ``backend`` selects the kernel backend (kernels.dispatch): window
    blocks route to the Pallas window-attention kernel, global blocks to
    the Pallas flash kernel; ``"xla"`` keeps the pure-jnp paths.

    Length-bucketed padded sequences thread their traced validity here:
    ``kv_len`` (B,) masks pad KEYS out of global attention (the sdpa
    masked path — never routed to the Pallas flash kernel), ``win_valid``
    (B,) flags whole pad windows for window attention.
    """
    q, k, v = _project_qkv(cfg, p, x, positions, rope)
    if window > 0:
        out = window_sdpa(q, k, v, window, win_valid=win_valid,
                          backend=backend)
    else:
        out = sdpa(q, k, v, causal=causal, kv_len=kv_len, backend=backend)
    if _HEAD_TAP is not None and not isinstance(out, jax.core.Tracer):
        _HEAD_TAP.append(np.asarray(jnp.mean(
            jnp.abs(out.astype(jnp.float32)), axis=(0, 1, 3))))
    out = qt.matmul(out.reshape(x.shape[0], x.shape[1], cfg.q_dim),
                    p["w_o"])
    if cfg.attention_bias:
        out = out + p["b_o"]
    return out


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_prefill(cfg: ModelConfig, p, x, positions, cache, *,
                      rope: bool = True):
    """Prefill: run causal attention and write k/v into the cache at [0,T)."""
    q, k, v = _project_qkv(cfg, p, x, positions, rope)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, 0, 0)),
    }
    out = sdpa(q, k, v, causal=True)
    out = qt.matmul(out.reshape(x.shape[0], x.shape[1], cfg.q_dim),
                    p["w_o"])
    if cfg.attention_bias:
        out = out + p["b_o"]
    return out, cache


def attention_decode(cfg: ModelConfig, p, x, pos, cache, *,
                     rope: bool = True):
    """One-token decode. x: (B,1,D); pos: scalar absolute position."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos)
    q, k, v = _project_qkv(cfg, p, x, positions, rope)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0)),
    }
    kv_len = jnp.full((B,), pos + 1)
    out = sdpa(q, cache["k"], cache["v"], kv_len=kv_len)
    out = qt.matmul(out.reshape(B, 1, cfg.q_dim), p["w_o"])
    if cfg.attention_bias:
        out = out + p["b_o"]
    return out, cache


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)


def init_cross_attention(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 4)
    return {
        "w_q": dense_init(ks[0], (cfg.d_model, cfg.q_dim), dtype),
        "w_k": dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dtype),
        "w_v": dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dtype),
        "w_o": dense_init(ks[3], (cfg.q_dim, cfg.d_model), dtype),
    }


def cross_attention(cfg: ModelConfig, p, x, enc_out):
    B, T, _ = x.shape
    S = enc_out.shape[1]
    q = (x @ p["w_q"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = (enc_out @ p["w_k"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["w_v"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    out = sdpa(q, k, v, causal=False)
    return out.reshape(B, T, cfg.q_dim) @ p["w_o"]


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
#
# Cache stores only the compressed latent c_kv (rank kv_lora) plus the
# decoupled RoPE key k_rope — the paper's point: tiny KV cache.  Decode
# uses the weight-absorption trick: q_nope is mapped through W_uk into
# latent space so attention scores are computed against c_kv directly.


def init_mla(cfg: ModelConfig, key, dtype):
    m = cfg.mla
    ks = jax.random.split(key, 8)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], (cfg.d_model, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, cfg.n_heads * qk_head), dtype),
        "w_dkv": dense_init(ks[2], (cfg.d_model,
                                    m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[3], (m.kv_lora_rank,
                                   cfg.n_heads * m.qk_nope_head_dim), dtype),
        "w_uv": dense_init(ks[4], (m.kv_lora_rank,
                                   cfg.n_heads * m.v_head_dim), dtype),
        "w_o": dense_init(ks[5], (cfg.n_heads * m.v_head_dim, cfg.d_model), dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def _mla_q(cfg: ModelConfig, p, x, positions):
    m = cfg.mla
    B, T, _ = x.shape
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, T, cfg.n_heads, qk_head)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(cfg: ModelConfig, p, x, positions):
    m = cfg.mla
    dkv = x @ p["w_dkv"]
    c_kv = rms_norm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:][:, :, None, :]    # single shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(cfg: ModelConfig, p, x, positions, cache=None, pos=None):
    """MLA attention.  If ``cache`` is given with scalar ``pos`` -> decode;
    if cache given without pos -> prefill (writes [0,T)); else training."""
    m = cfg.mla
    B, T, _ = x.shape
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    decode = cache is not None and pos is not None
    c_new, kr_new = _mla_latents(cfg, p, x, positions)

    if cache is not None:
        off = pos if decode else 0
        cache = {
            "c_kv": jax.lax.dynamic_update_slice(
                cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, off, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["k_rope"], kr_new.astype(cache["k_rope"].dtype),
                (0, off, 0)),
        }
        c_kv, k_rope = cache["c_kv"], cache["k_rope"]
        S = c_kv.shape[1]
    else:
        c_kv, k_rope = c_new, kr_new
        S = T

    # absorb W_uk into the query:  score_nope = (q_nope @ W_uk^T) . c_kv
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, cfg.n_heads, m.v_head_dim)
    c_kv32 = c_kv.astype(jnp.float32)
    k_rope32 = k_rope.astype(jnp.float32)

    def attend(qn_blk, qr_blk, off):
        """One q block (B, C, H, *) at absolute offset ``off``."""
        Tc = qn_blk.shape[1]
        q_lat = jnp.einsum("bthd,lhd->bthl", qn_blk.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        logits = (jnp.einsum("bthl,bsl->bhts", q_lat, c_kv32) +
                  jnp.einsum("bthd,bsd->bhts", qr_blk.astype(jnp.float32),
                             k_rope32)) * scale
        if decode:
            valid = jnp.arange(S) < (pos + 1)              # (S,)
            logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
        else:
            q_pos = jnp.arange(Tc) + off
            mask = q_pos[:, None] >= jnp.arange(S)[None, :]
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhts,bsl->bthl", probs, c_kv32)
        return jnp.einsum("bthl,lhd->bthd", o_lat, w_uv.astype(jnp.float32))

    if T > 2 * Q_CHUNK and T % Q_CHUNK == 0:
        nb = T // Q_CHUNK
        qn = jnp.moveaxis(q_nope.reshape(B, nb, Q_CHUNK, *q_nope.shape[2:]),
                          1, 0)
        qr = jnp.moveaxis(q_rope.reshape(B, nb, Q_CHUNK, *q_rope.shape[2:]),
                          1, 0)
        offs = jnp.arange(nb) * Q_CHUNK

        def body(_, inp):
            return None, attend(inp[0], inp[1], inp[2])

        from repro.models.layers import scan as _scan
        _, outs = _scan(body, None, (qn, qr, offs))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, T, cfg.n_heads,
                                               m.v_head_dim)
    else:
        out = attend(q_nope, q_rope, 0)
    out = out.reshape(B, T, cfg.n_heads * m.v_head_dim).astype(x.dtype)
    out = out @ p["w_o"]
    return (out, cache) if cache is not None else out
