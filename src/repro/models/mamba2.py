"""Mamba-2 block (state-space duality / SSD, arXiv:2405.21060).

Chunked SSD: within-chunk quadratic form (MXU-friendly matmuls) +
inter-chunk linear state recurrence (lax.scan).  Decode is an O(1)
recurrent state update — the reason ``long_500k`` is runnable for
SSM/hybrid archs while pure-attention archs are skipped.

All SSD math in fp32; projections in the model dtype.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def init_mamba2(cfg: ModelConfig, key, dtype):
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    # dt bias st. softplus(dt_bias) spans [dt_min, dt_max]
    u = jax.random.uniform(ks[2], (H,), jnp.float32)
    dt = jnp.exp(u * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))          # inverse softplus
    return {
        "w_in": dense_init(ks[0], (cfg.d_model, proj_out), dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[3], (d_inner, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# causal depthwise conv1d


def causal_conv1d(x, w, b):
    """x: (B, T, C); w: (K, C) depthwise; left-padded causal."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # sum_k w[k] * x[t - (K-1) + k]
    out = jnp.zeros_like(x)
    for k in range(K):
        out = out + xp[:, k:k + x.shape[1], :] * w[k][None, None, :]
    return out + b[None, None, :]


def conv1d_step(x_t, conv_state, w, b):
    """One-step conv: x_t (B, C); conv_state (B, K-1, C) of past inputs."""
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b[None, :]
    new_state = window[:, 1:, :]
    return out, new_state


# ---------------------------------------------------------------------------
# chunked SSD core (pure jnp; the Pallas kernel mirrors the intra-chunk part)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                return_final_state: bool = False):
    """SSD over a full sequence.

    x:  (b, T, H, P) — dt-scaled inputs are formed internally
    dt: (b, T, H)    — post-softplus step sizes
    A:  (H,)         — negative decay rates
    Bm/Cm: (b, T, G, N)
    """
    b, T, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    T0 = T
    if T % chunk:
        # zero-pad to a chunk multiple: dt=0 rows are state-neutral
        # (dA=0 -> decay 1, xbar=0) so the recurrence is unaffected.
        pad = chunk - T % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        T = T + pad
    nc = T // chunk

    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, H, Pd).astype(f32)
    dtc = dt.reshape(b, nc, chunk, H).astype(f32)
    Bh = jnp.repeat(Bm.reshape(b, nc, chunk, G, N), hpg, axis=3).astype(f32)
    Ch = jnp.repeat(Cm.reshape(b, nc, chunk, G, N), hpg, axis=3).astype(f32)

    dA = dtc * A[None, None, None, :]                 # (b,nc,Q,H), negative
    cum = jnp.cumsum(dA, axis=2)                      # within-chunk log decay
    xbar = xc * dtc[..., None]

    # intra-chunk: Y[i] = sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) xbar_j
    Lmask = jnp.tril(jnp.ones((chunk, chunk), bool))
    ldec = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (b,nc,i,j,H)
    ldec = jnp.where(Lmask[None, None, :, :, None], ldec, -jnp.inf)
    scores = jnp.einsum("bnihd,bnjhd->bnijh", Ch, Bh)
    Y = jnp.einsum("bnijh,bnjhp->bnihp", scores * jnp.exp(ldec), xbar)

    # chunk-local end states: S_loc = sum_j exp(cum_Q - cum_j) B_j xbar_j^T
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (b,nc,Q,H)
    S_loc = jnp.einsum("bnjhd,bnjhp->bnhdp", Bh * dec_to_end[..., None], xbar)

    # inter-chunk recurrence over nc
    chunk_dec = jnp.exp(cum[:, :, -1, :])                    # (b,nc,H)
    s0 = (jnp.zeros((b, H, N, Pd), f32) if init_state is None
          else init_state.astype(f32))

    def step(s_prev, inp):
        dec, s_l = inp                                       # (b,H),(b,H,N,P)
        s_new = s_prev * dec[:, :, None, None] + s_l
        return s_new, s_prev

    from repro.models.layers import scan as _scan
    s_final, s_prevs = _scan(
        step, s0, (jnp.moveaxis(chunk_dec, 1, 0), jnp.moveaxis(S_loc, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                    # (b,nc,H,N,P)

    Y = Y + jnp.einsum("bnihd,bnhdp->bnihp",
                       Ch * jnp.exp(cum)[..., None], s_prevs)
    Y = Y.reshape(b, T, H, Pd)[:, :T0]
    if return_final_state:
        return Y, s_final
    return Y


def ssd_decode_step(x_t, dt_t, A, B_t, C_t, state):
    """One-token SSD: x_t (b,H,P), dt_t (b,H), B_t/C_t (b,G,N),
    state (b,H,N,P) -> (y_t, new_state)."""
    b, H, Pd = x_t.shape
    G, N = B_t.shape[1], B_t.shape[2]
    hpg = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(B_t, hpg, axis=1).astype(f32)            # (b,H,N)
    Ch = jnp.repeat(C_t, hpg, axis=1).astype(f32)
    dA = jnp.exp(dt_t.astype(f32) * A[None, :])              # (b,H)
    xbar = x_t.astype(f32) * dt_t[..., None].astype(f32)
    new_state = state * dA[:, :, None, None] + \
        jnp.einsum("bhd,bhp->bhdp", Bh, xbar)
    y = jnp.einsum("bhd,bhdp->bhp", Ch, new_state)
    return y, new_state


# ---------------------------------------------------------------------------
# full block


def _split_proj(cfg: ModelConfig, z_all):
    s = cfg.ssm
    d_inner, H, _ = ssm_dims(cfg)
    gN = s.n_groups * s.d_state
    z, xBC_dt = jnp.split(z_all, [d_inner], axis=-1)
    xBC, dt_raw = jnp.split(xBC_dt, [d_inner + 2 * gN], axis=-1)
    return z, xBC, dt_raw


def mamba2_forward(cfg: ModelConfig, p, x, *, use_kernel: bool = False):
    """Full-sequence Mamba-2 block.  x: (B, T, D) -> (B, T, D)."""
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    B_, T, D = x.shape
    gN = s.n_groups * s.d_state

    z, xBC, dt_raw = _split_proj(cfg, x @ p["w_in"])
    xBC = jax.nn.silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + gN], axis=-1)
    xs = xs.reshape(B_, T, H, s.head_dim)
    Bm = Bm.reshape(B_, T, s.n_groups, s.d_state)
    Cm = Cm.reshape(B_, T, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    chunk = min(s.chunk_size, T)
    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y = ssd_ops.ssd(xs, dt, A, Bm, Cm, chunk)
    else:
        y = ssd_chunked(xs, dt, A, Bm, Cm, chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, T, d_inner).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["w_out"]


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner, H, conv_ch = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
    }


def mamba2_decode(cfg: ModelConfig, p, x_t, state):
    """One-token recurrent step.  x_t: (B, 1, D)."""
    s = cfg.ssm
    d_inner, H, _ = ssm_dims(cfg)
    gN = s.n_groups * s.d_state
    B_ = x_t.shape[0]

    z, xBC, dt_raw = _split_proj(cfg, x_t[:, 0, :] @ p["w_in"])
    xBC, conv_state = conv1d_step(xBC, state["conv"], p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + gN], axis=-1)
    xs = xs.reshape(B_, H, s.head_dim)
    Bm = Bm.reshape(B_, s.n_groups, s.d_state)
    Cm = Cm.reshape(B_, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, ssm_state = ssd_decode_step(xs, dt, A, Bm, Cm, state["ssm"])
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, d_inner).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None, :]
    return out, {"conv": conv_state, "ssm": ssm_state}
