"""Architecture registry: one dispatch surface over every model family.

Entry points (all pure functions over (cfg, params, ...)):
  init_params(cfg, key, dtype)
  forward_hidden(cfg, params, batch, ctx)      -> (hidden, aux)   training
  init_decode_state(cfg, batch, max_len, dtype)                    serving
  prefill(cfg, params, batch, state, ctx)      -> (hidden, state, aux)
  decode_step(cfg, params, token, pos, state, ctx) -> (logits, state)
  count_params_analytic(cfg)                   analytic N for 6·N·D rooflines
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import hybrid as hyb
from repro.models import ssm_lm
from repro.models import transformer as tfm
from repro.models import whisper as whs
from repro.models.config import ModelConfig
from repro.models.transformer import LOCAL, ParallelCtx


# ---------------------------------------------------------------------------
# init


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    if cfg.family == "ssm":
        return ssm_lm.init_ssm_params(cfg, key, dtype)
    if cfg.family == "hybrid":
        return hyb.init_hybrid_params(cfg, key, dtype)
    if cfg.family == "encdec":
        return whs.init_whisper_params(cfg, key, dtype)
    if cfg.family == "vit":
        from repro.core import vit_backbone
        return vit_backbone.init_vitdet_params(cfg, key, dtype)
    return tfm.init_lm_params(cfg, key, dtype)            # dense / moe / vlm


# ---------------------------------------------------------------------------
# training forward


def forward_hidden(cfg: ModelConfig, params, batch: Dict[str, Any],
                   ctx: ParallelCtx = LOCAL):
    """batch: {"tokens": (B,T)} plus family extras ("frames"/"image_embeds")."""
    if cfg.family == "ssm":
        return ssm_lm.forward_hidden(cfg, params, batch["tokens"], ctx)
    if cfg.family == "hybrid":
        return hyb.forward_hidden(cfg, params, batch["tokens"], ctx)
    if cfg.family == "encdec":
        return whs.decode_train(cfg, params, batch["tokens"], batch["frames"],
                                ctx)
    return tfm.forward_hidden(cfg, params, batch["tokens"], ctx,
                              image_embeds=batch.get("image_embeds"))


CE_CHUNK_ELEMS = 64 * 2 ** 20      # chunk the CE when T*V exceeds this


def _ce_nll_dense(logits, targets):
    # vocab-parallel-friendly CE: lse + masked pick (no gather over the
    # model-sharded vocab axis — GSPMD lowers this to one small psum)
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits32.shape,
                                    logits32.ndim - 1)
    picked = jnp.sum(jnp.where(iota == targets[..., None], logits32, 0.0),
                     axis=-1)
    return lse - picked


def _ce_nll(logits, targets):
    """Per-token NLL, time-chunked when the f32 logits buffer would be
    large (odd vocabs can't always shard over model — e.g. whisper's
    51865 — so the buffer must be bounded explicitly)."""
    B, T, V = logits.shape
    chunk = max(CE_CHUNK_ELEMS // max(V, 1), 128)
    chunk = 1 << (chunk.bit_length() - 1)       # floor to a power of two
    while chunk > 128 and T % chunk:            # ...that divides T
        chunk //= 2
    if T <= chunk or T % chunk:
        return _ce_nll_dense(logits, targets)
    nb = T // chunk

    def body(_, inp):
        lg, tg = inp
        return None, _ce_nll_dense(lg, tg)

    lg = jnp.moveaxis(logits.reshape(B, nb, chunk, V), 1, 0)
    tg = jnp.moveaxis(targets.reshape(B, nb, chunk), 1, 0)
    from repro.models.layers import scan as _scan
    _, nll = _scan(body, None, (lg, tg))
    return jnp.moveaxis(nll, 0, 1).reshape(B, T)


def lm_loss(cfg: ModelConfig, params, batch, ctx: ParallelCtx = LOCAL):
    """Next-token cross entropy (+ MoE aux). Returns (loss, metrics)."""
    hidden, aux = forward_hidden(cfg, params, batch, ctx)
    logits = tfm.logits_from_hidden(cfg, params, hidden, ctx)
    tokens = batch["tokens"]
    # VLM prepends image tokens to the sequence: only score the text tail.
    T_text = tokens.shape[1]
    logits = logits[:, -T_text:, :]
    targets = batch.get("labels")
    if targets is None:
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    nll = _ce_nll(logits, targets)
    mask = jnp.ones_like(nll)
    if "loss_mask" in batch:
        mask = batch["loss_mask"].astype(nll.dtype)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    aux_coef = cfg.moe.router_aux_coef if cfg.moe is not None else 0.0
    total = loss + aux_coef * aux
    return total, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype):
    if cfg.family == "ssm":
        return ssm_lm.init_states(cfg, batch, dtype)
    if cfg.family == "hybrid":
        return hyb.init_hybrid_caches(cfg, batch, max_len, dtype)
    if cfg.family == "encdec":
        # (enc_out placeholder, decoder self-attn caches); enc_out is
        # produced at prefill.
        return whs.init_dec_caches(cfg, batch, max_len, dtype)
    return tfm.init_caches(cfg, batch, max_len, dtype)


def prefill(cfg: ModelConfig, params, batch: Dict[str, Any], state,
            ctx: ParallelCtx = LOCAL):
    if cfg.family == "ssm":
        return ssm_lm.prefill(cfg, params, batch["tokens"], state, ctx)
    if cfg.family == "hybrid":
        return hyb.prefill(cfg, params, batch["tokens"], state, ctx)
    if cfg.family == "encdec":
        return whs.prefill(cfg, params, batch["tokens"], batch["frames"],
                           state, ctx)
    return tfm.prefill(cfg, params, batch["tokens"], state, ctx,
                       image_embeds=batch.get("image_embeds"))


def decode_step(cfg: ModelConfig, params, token, pos, state,
                ctx: ParallelCtx = LOCAL):
    if cfg.family == "ssm":
        return ssm_lm.decode_step(cfg, params, token, pos, state, ctx)
    if cfg.family == "hybrid":
        return hyb.decode_step(cfg, params, token, pos, state, ctx)
    if cfg.family == "encdec":
        return whs.decode_step(cfg, params, token, pos, state, ctx)
    return tfm.decode_step(cfg, params, token, pos, state, ctx)


# ---------------------------------------------------------------------------
# analytic parameter counts (for MODEL_FLOPS = 6 N D rooflines)


def _attn_params(cfg: ModelConfig) -> int:
    if cfg.mla is not None:
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        return (cfg.d_model * m.q_lora_rank
                + m.q_lora_rank * cfg.n_heads * qk_head
                + cfg.d_model * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * cfg.n_heads * m.qk_nope_head_dim
                + m.kv_lora_rank * cfg.n_heads * m.v_head_dim
                + cfg.n_heads * m.v_head_dim * cfg.d_model)
    return cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * cfg.d_model


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    if cfg.activation == "silu":
        return 3 * cfg.d_model * d_ff
    return 2 * cfg.d_model * d_ff


def _mamba_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    from repro.models.mamba2 import ssm_dims
    d_inner, H, conv_ch = ssm_dims(cfg)
    proj_out = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    return (cfg.d_model * proj_out + s.d_conv * conv_ch + conv_ch
            + 3 * H + d_inner + d_inner * cfg.d_model)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    D = cfg.d_model
    embed = cfg.vocab_size * D
    head = 0 if cfg.tied_embeddings else D * cfg.vocab_size
    total = embed + head

    if cfg.family == "ssm":
        return total + cfg.n_layers * _mamba_params(cfg)

    if cfg.family == "hybrid":
        per_mamba = _mamba_params(cfg)
        shared = _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
        return total + cfg.n_layers * per_mamba + shared

    if cfg.family == "encdec":
        enc = cfg.encdec.n_encoder_layers * (
            _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff))
        dec = cfg.n_layers * (2 * _attn_params(cfg) +
                              _mlp_params(cfg, cfg.d_ff))
        return total + enc + dec + cfg.max_seq_len * D

    # dense / moe / vlm decoder
    attn_p = _attn_params(cfg)
    if cfg.moe is None:
        return total + cfg.n_layers * (attn_p + _mlp_params(cfg, cfg.d_ff))

    m = cfg.moe
    n_dense = m.first_dense_layers
    n_moe = cfg.n_layers - n_dense
    dense_ffn = _mlp_params(cfg, m.d_ff_dense or cfg.d_ff)
    expert_ffn = _mlp_params(cfg, m.d_ff_expert)
    shared_ffn = _mlp_params(cfg, m.d_ff_expert * m.n_shared_experts) \
        if m.n_shared_experts else 0
    router = D * m.n_experts
    n_eff = m.top_k if active_only else m.n_experts
    total += n_dense * (attn_p + dense_ffn)
    total += n_moe * (attn_p + router + n_eff * expert_ffn + shared_ffn)
    return total
