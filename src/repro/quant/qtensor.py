"""QuantTensor: the per-output-channel int8 weight container, and the
quant-aware matmul every linear use-site routes through.

A ``QuantTensor`` is a registered pytree (``q`` int8 codes + ``scale``
f32 per-output-channel, with the target float dtype as static aux), so
quantized parameter trees flow through ``jax.jit`` / ``tree_map`` /
checkpoint utilities like any other params — ``forward_features``
consumes them transparently because every matmul site calls
:func:`matmul` instead of ``@``.

Quantization is symmetric per OUTPUT channel (the last axis of a
``(K, N)`` weight or the ``cout`` axis of an ``(k, k, cin, cout)`` conv
weight): ``w ~= q * scale[None, :]`` with ``scale = max|w| / 127`` per
column.  Activations are quantized dynamically per row at matmul time
(``sx = max|x| / 127``), which keeps the lane calibration-free for
activations — the accuracy gate (quant.calibrate) only has to pick the
(weight dtype, pruning) point.

Execution mode (kernels.dispatch.resolve_quant):

  "native"   int8 x int8 -> int32 GEMM + dequant epilogue
             (dispatch.int8_matmul: Pallas kernel / dot_general).
  "dequant"  dequantize the weight and run the plain float GEMM — the
             oracle lane for parity tests and a safe fallback.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch


@jax.tree_util.register_pytree_node_class
@dataclass
class QuantTensor:
    """int8 codes + per-output-channel f32 scales for one weight.

    ``q``: int8, any shape with the output channel LAST; ``scale``:
    f32 (q.shape[-1],); ``out_dtype``: dtype NAME string (static aux —
    strings hash/compare cleanly across jit cache keys) the dequantized
    weight and matmul outputs are produced in.
    """
    q: jnp.ndarray
    scale: jnp.ndarray
    out_dtype: str = "float32"

    def tree_flatten(self):
        return (self.q, self.scale), (self.out_dtype,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def nbytes(self):
        return self.q.nbytes + self.scale.nbytes

    def dequant(self, dtype=None) -> jnp.ndarray:
        """The float weight ``q * scale`` in ``dtype`` (default
        ``out_dtype``)."""
        w = self.q.astype(jnp.float32) * self.scale.astype(jnp.float32)
        return w.astype(dtype if dtype is not None else self.out_dtype)


WeightLike = Union[jnp.ndarray, QuantTensor]


def quantize_weight(w, out_dtype=jnp.float32,
                    stacked: bool = False) -> QuantTensor:
    """Symmetric per-output-channel int8 quantization of a float weight
    (output channel = last axis).  ``stacked``: the leading axis is a
    scan-stacked layer axis — scales are per (layer, output channel),
    kept broadcast-shaped (L, 1, ..., N) so ``lax.scan`` slices the
    QuantTensor children layer-by-layer like any stacked param."""
    w32 = jnp.asarray(w).astype(jnp.float32)
    red = tuple(range(1 if stacked else 0, w32.ndim - 1))
    amax = jnp.max(jnp.abs(w32), axis=red, keepdims=stacked)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return QuantTensor(q, scale.astype(jnp.float32),
                       jnp.dtype(out_dtype).name)


def asarray(w: WeightLike, dtype=None) -> jnp.ndarray:
    """Dequantize a QuantTensor; pass plain arrays through."""
    if isinstance(w, QuantTensor):
        return w.dequant(dtype)
    return w if dtype is None else w.astype(dtype)


def concat_out(ws: Sequence[WeightLike]) -> WeightLike:
    """Concatenate weights along the OUTPUT axis (axis=1 of (K, N)) —
    the fused-QKV helper.  Per-output-channel scales concatenate
    losslessly, so the fused quantized GEMM stays column-for-column
    identical to three separate ones."""
    if any(isinstance(w, QuantTensor) for w in ws):
        assert all(isinstance(w, QuantTensor) for w in ws), \
            "cannot fuse quantized and unquantized weights"
        return QuantTensor(jnp.concatenate([w.q for w in ws], axis=1),
                           jnp.concatenate([w.scale for w in ws]),
                           ws[0].out_dtype)
    return jnp.concatenate(list(ws), axis=1)


def _quantize_rows(x2: jnp.ndarray):
    """Dynamic symmetric per-row int8 activation quantization."""
    amax = jnp.max(jnp.abs(x2), axis=1)
    sx = jnp.maximum(amax, 1e-12) / 127.0
    xq = jnp.clip(jnp.round(x2 / sx[:, None]), -127, 127).astype(jnp.int8)
    return xq, sx


def matmul(x: jnp.ndarray, w: WeightLike, *,
           mode: Optional[str] = None,
           backend: Optional[str] = None) -> jnp.ndarray:
    """``x @ w`` with quant-aware routing.

    Plain float weights: the half-precision lane casts activations to
    the weight dtype (so an fp16/bf16 parameter tree carries fp16
    activations through the whole backbone); fp32 stays the exact
    original ``x @ w``.  QuantTensor weights run the int8 lane (mode
    "native") or the dequantized float GEMM (mode "dequant") — see
    kernels.dispatch.resolve_quant for precedence.
    """
    if not isinstance(w, QuantTensor):
        if w.dtype != x.dtype and w.dtype in (jnp.float16, jnp.bfloat16):
            x = x.astype(w.dtype)
        return x @ w
    if dispatch.resolve_quant(mode) == "dequant":
        wd = w.dequant()
        return x.astype(wd.dtype) @ wd
    lead = x.shape[:-1]
    Kd = x.shape[-1]
    xq, sx = _quantize_rows(x.reshape(-1, Kd).astype(jnp.float32))
    # a scan-sliced stacked weight arrives as (K, N) codes with a
    # broadcast-shaped (1, N) scale — flatten to the kernel's (N,)
    y = dispatch.int8_matmul(xq, w.q, sx, w.scale.reshape(-1),
                             out_dtype=jnp.dtype(w.out_dtype),
                             backend=backend)
    return y.reshape(*lead, w.q.shape[-1])


def tree_bytes(tree) -> int:
    """Total parameter bytes of a pytree (QuantTensor leaves count their
    int8 codes + scales)."""
    return int(sum(getattr(l, "nbytes", 0) or np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(tree)))


def cast_tree(tree, dtype):
    """Cast every float leaf to ``dtype``.  QuantTensor leaves keep
    their int8 codes and f32 scales (precision of the dequant epilogue)
    but retarget their output dtype — this is how the activation-dtype
    knob composes with the int8 weight lane."""
    dt = jnp.dtype(dtype)

    def cast(x):
        if isinstance(x, QuantTensor):
            return QuantTensor(x.q, x.scale, dt.name)
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dt)
        return x

    return jax.tree_util.tree_map(
        cast, tree, is_leaf=lambda x: isinstance(x, QuantTensor))
