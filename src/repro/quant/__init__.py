"""Post-training quantization & head pruning for the serving lane.

  qtensor    QuantTensor pytree, quant-aware matmul, tree utilities
  ptq        QuantSpec + compress(): the (weight dtype, act dtype,
             pruned heads) point applied to a ViTDet parameter tree
  prune      head scoring (calibration-frame tap) + re-packing
  calibrate  the accuracy gate: rendering-F1 delta bound on the
             calibration scenarios decides which point ships

``prune`` and ``calibrate`` import model/serving modules, so they load
lazily — ``qtensor`` must stay importable from models.attention and
models.layers without cycles.
"""
from repro.quant.ptq import (DEFAULT_CANDIDATES, DTYPES,  # noqa: F401
                             QuantSpec, compress,
                             quantize_lm_params, quantize_vitdet_params)
from repro.quant.qtensor import (QuantTensor, asarray,  # noqa: F401
                                 cast_tree, concat_out, matmul,
                                 quantize_weight, tree_bytes)

__all__ = [
    "QuantTensor", "QuantSpec", "DEFAULT_CANDIDATES", "DTYPES",
    "quantize_weight", "matmul", "asarray", "concat_out", "cast_tree",
    "tree_bytes", "compress", "quantize_vitdet_params",
    "quantize_lm_params", "prune", "calibrate",
]


def __getattr__(name):
    if name in ("prune", "calibrate"):
        import importlib
        return importlib.import_module(f"repro.quant.{name}")
    raise AttributeError(name)
