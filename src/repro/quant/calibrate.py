"""Accuracy-gated calibration: which compression point ships.

Same discipline as the reuse/coalescing gates: ground truth is the
fp32 full-resolution model's detections (the paper's rendering-accuracy
definition), the metric is median rendering F1 over calibration clips,
and a candidate passes when its F1 delta vs the fp32 model stays within
``bound`` on EVERY calibration scenario — evaluated on both the
full-resolution workload and the mixed-resolution serving workload
(motion-derived plans at the deployment beta), so a quantization error
that only shows up under mixed-res packing still trips the gate.

:func:`calibrate` walks the candidate ladder ordered by compressed
parameter bytes (most compressed first) and ships the FIRST point that
holds the bound; if none do, the deployment stays fp32 (shipped is
None).  ``ServerModel(cfg, params, quant=shipped)`` then compiles the
16-executable grid against the compressed tree.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.models.config import ModelConfig
from repro.quant import qtensor as qt
from repro.quant.ptq import DEFAULT_CANDIDATES, QuantSpec, compress

F1_BOUND = 0.005
SCENARIOS = ("parkS", "driveN")


@dataclass
class CalibPoint:
    """One evaluated candidate."""
    spec: QuantSpec
    bytes: int
    ratio: float
    deltas: Dict[str, float] = field(default_factory=dict)
    passed: bool = False


@dataclass
class CalibReport:
    shipped: Optional[QuantSpec]
    points: List[CalibPoint]
    bound: float
    scenarios: Tuple[str, ...]
    bytes_fp32: int


def _median_f1(dets_a: List, dets_b: List) -> float:
    from repro.offload.detection import frame_f1
    return float(np.median([frame_f1(a, b)
                            for a, b in zip(dets_a, dets_b)]))


def _scenario_workload(cfg: ModelConfig, scenario: str, n_frames: int,
                       seed: int):
    """Calibration frames + per-frame serving masks (object-free
    regions downsampled, the fig-5 workload)."""
    from repro.core import vit_backbone as vb
    from repro.data import synthetic_video as sv
    from repro.offload import motion as mo
    part = vb.vit_partition(cfg)
    frames, gts = sv.make_clip(scenario, n_frames,
                               size=cfg.vit.img_size[0], seed=seed)
    masks = [(mo.region_density(g, part, cfg.vit.patch_size) == 0)
             .astype(np.int32) for g in gts]
    return frames, masks


def scenario_delta(ref_server, cand_server, frames, masks,
                   beta: int) -> float:
    """max F1 delta of the candidate vs the fp32 reference on one clip,
    over the full-res and mixed-res workloads.  Ground truth is the
    reference model's FULL-RES detections."""
    gt = [ref_server.infer(f) for f in frames]

    def run(server):
        full = [server.infer(f) for f in frames]
        mixed = [server.infer(f, m if m.sum() else None,
                              beta if m.sum() else 0)
                 for f, m in zip(frames, masks)]
        return full, mixed

    ref_full, ref_mixed = (gt, [ref_server.infer(f, m if m.sum() else
                                                 None,
                                                 beta if m.sum() else 0)
                                for f, m in zip(frames, masks)])
    cand_full, cand_mixed = run(cand_server)
    d_full = _median_f1(gt, ref_full) - _median_f1(gt, cand_full)
    d_mixed = _median_f1(gt, ref_mixed) - _median_f1(gt, cand_mixed)
    return float(max(d_full, d_mixed))


def calibrate(cfg: ModelConfig, params,
              candidates: Sequence[QuantSpec] = DEFAULT_CANDIDATES,
              scenarios: Sequence[str] = SCENARIOS,
              bound: float = F1_BOUND, n_frames: int = 8, beta: int = 2,
              seed: int = 23, server_kw: Optional[Dict] = None,
              calib_frames: Optional[Sequence[np.ndarray]] = None
              ) -> CalibReport:
    """Walk the candidate ladder and pick the shipped point.

    ``server_kw`` forwards to ServerModel (backend, jit, buckets...).
    ``calib_frames`` feeds head scoring for pruned candidates (default:
    the first scenario's frames).
    """
    from repro.offload.simulator import ServerModel
    kw = dict(server_kw or {})
    ref = ServerModel(cfg, params, **kw)
    bytes0 = qt.tree_bytes(params)

    workloads = [(s,) + _scenario_workload(cfg, s, n_frames, seed)
                 for s in scenarios]
    if calib_frames is None and workloads:
        calib_frames = workloads[0][1][:4]

    # evaluate most-compressed-first: compress once per candidate, order
    # by actual byte count, ship the first that holds the bound
    compressed = []
    for spec in candidates:
        ccfg, cparams, rep = compress(cfg, params, spec,
                                      calib_frames=calib_frames)
        compressed.append((rep["bytes"], spec, ccfg, cparams, rep))
    compressed.sort(key=lambda t: t[0])

    points: List[CalibPoint] = []
    shipped: Optional[QuantSpec] = None
    for nbytes, spec, ccfg, cparams, rep in compressed:
        cand = ServerModel(ccfg, cparams, **kw)
        point = CalibPoint(spec=spec, bytes=nbytes, ratio=rep["ratio"])
        for sname, frames, masks in workloads:
            point.deltas[sname] = scenario_delta(ref, cand, frames,
                                                 masks, beta)
        point.passed = all(d <= bound for d in point.deltas.values())
        points.append(point)
        if point.passed and shipped is None:
            shipped = spec
            break                      # most compressed passing point
    return CalibReport(shipped=shipped, points=points, bound=bound,
                       scenarios=tuple(scenarios), bytes_fp32=bytes0)
