"""Post-training compression of the ViTDet parameter tree.

``QuantSpec`` names one point in the (weight dtype, activation dtype,
pruned heads) space; :func:`compress` applies it — head pruning first
(float slicing), then weight quantization / casting — and returns the
re-packed ``(cfg, params, report)``.  The result is a drop-in params
pytree: ``forward_features`` and the serving executables consume it
transparently (every linear use-site routes through
quant.qtensor.matmul), so ``ServerModel(cfg, params, quant=spec)`` is
the whole deployment story.

Weight dtypes:

  "fp32"  identity (the baseline lane)
  "fp16" / "bf16"  cast every float leaf; matmul sites cast
          activations to match, so the whole backbone runs half
  "int8"  per-output-channel symmetric QuantTensors for every linear
          weight — fused QKV, w_o, MLP, patch embed, pos-emb grid and
          the detection-head convs; biases and norm affines stay float
          (they are < 1% of bytes and norm math runs f32 internally)

The activation dtype knob composes: ``act_dtype="fp16"`` casts the
residual-stream leaves (biases, norms, pos-emb) and retargets every
QuantTensor's output dtype, so int8 weights can feed fp16 activations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.quant import qtensor as qt

DTYPES = {"fp32": jnp.float32, "fp16": jnp.float16, "bf16": jnp.bfloat16}


@dataclass(frozen=True)
class QuantSpec:
    """One deployment compression point."""
    weight_dtype: str = "int8"        # fp32 | fp16 | bf16 | int8
    act_dtype: str = "fp32"           # fp32 | fp16 | bf16
    prune_heads: int = 0              # heads dropped per layer

    def __post_init__(self):
        assert self.weight_dtype in ("fp32", "fp16", "bf16", "int8"), \
            self.weight_dtype
        assert self.act_dtype in DTYPES, self.act_dtype

    @property
    def act_jnp(self):
        return DTYPES[self.act_dtype]

    @property
    def name(self) -> str:
        n = self.weight_dtype
        if self.act_dtype != "fp32":
            n += f"+{self.act_dtype}"
        if self.prune_heads:
            n += f"-p{self.prune_heads}"
        return n


# the default candidate ladder the calibration gate walks, most
# compressed first (quant.calibrate orders by actual compressed bytes)
DEFAULT_CANDIDATES: Tuple[QuantSpec, ...] = (
    QuantSpec("int8", "fp16", 1),
    QuantSpec("int8", "fp16", 0),
    QuantSpec("int8", "fp32", 0),
    QuantSpec("fp16", "fp16", 0),
)


def quantize_vitdet_params(params, out_dtype=jnp.float32):
    """Per-output-channel int8 QuantTensors for every linear weight of
    the ViTDet tree (QKV / w_o / MLP / patch embed / pos-emb grid /
    detection-head convs); biases and norm affines pass through."""
    odt = jnp.dtype(out_dtype)

    def qz(w):
        return qt.quantize_weight(w, out_dtype=odt)

    def conv(c):
        return {**c, "w": qz(c["w"])}

    blocks = []
    for blk in params["blocks"]:
        a = dict(blk["attn"])
        for key in ("w_q", "w_k", "w_v", "w_o"):
            a[key] = qz(a[key])
        f = dict(blk["ffn"])
        for key in ("w_up", "w_down", "w_gate"):
            if key in f:
                f[key] = qz(f[key])
        blocks.append({**blk, "attn": a, "ffn": f})
    head = dict(params["head"])
    head["lateral"] = [conv(c) for c in head["lateral"]]
    head["smooth"] = [conv(c) for c in head["smooth"]]
    for key in ("tower", "cls", "box", "ctr"):
        head[key] = conv(head[key])
    return {
        **params,
        "patch_embed": {**params["patch_embed"],
                        "w": qz(params["patch_embed"]["w"])},
        "pos_emb": qz(params["pos_emb"]),
        "blocks": blocks,
        "head": head,
    }


def quantize_lm_params(params, out_dtype=jnp.float32):
    """Generic tree walk for the LM serving lane: quantize the
    projection weights every transformer block shares with the ViT
    (attention + MLP matmuls route through qtensor.matmul there too);
    embeddings and norms pass through (gathers don't dequantize).
    3-D weights are scan-stacked ``(n_layers, K, N)`` blocks — they
    quantize with per-layer scales shaped to survive ``lax.scan``
    slicing (qtensor.quantize_weight stacked mode)."""
    odt = jnp.dtype(out_dtype)
    TARGETS = {"w_q", "w_k", "w_v", "w_o", "w_up", "w_down", "w_gate"}

    def walk(node):
        if isinstance(node, dict):
            return {k: (qt.quantize_weight(v, out_dtype=odt,
                                           stacked=v.ndim == 3)
                        if k in TARGETS and getattr(v, "ndim", 0) in (2, 3)
                        else walk(v))
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


def compress(cfg: ModelConfig, params, spec: QuantSpec,
             calib_frames: Optional[Sequence[np.ndarray]] = None,
             head_scores: Optional[np.ndarray] = None):
    """Apply ``spec`` to a float ViTDet tree.

    Returns ``(cfg, params, report)`` — cfg shrinks ``n_heads`` when
    pruning, params carries QuantTensors / half casts, and the report
    records bytes before/after, the compression ratio, and which heads
    each layer dropped (for the dense-parity tests and the bench).
    """
    bytes0 = qt.tree_bytes(params)
    report: Dict = {"spec": spec.name, "weight_dtype": spec.weight_dtype,
                    "act_dtype": spec.act_dtype,
                    "prune_heads": spec.prune_heads, "bytes_fp32": bytes0}
    if spec.prune_heads:
        from repro.quant import prune
        scores = head_scores
        if scores is None:
            scores = (prune.score_heads(cfg, params, calib_frames)
                      if calib_frames is not None and len(calib_frames)
                      else prune.w_o_head_norms(cfg, params))
        H = cfg.n_heads
        cfg, params, kept = prune.prune_heads(cfg, params,
                                              spec.prune_heads, scores)
        report["kept_heads"] = kept
        report["dropped_heads"] = [
            sorted(set(range(H)) - set(ks)) for ks in kept]
    adt = spec.act_jnp
    if spec.weight_dtype == "int8":
        params = quantize_vitdet_params(params, out_dtype=adt)
        if adt != jnp.float32:
            params = qt.cast_tree(params, adt)
    elif spec.weight_dtype in ("fp16", "bf16"):
        params = qt.cast_tree(params, DTYPES[spec.weight_dtype])
    elif adt != jnp.float32:
        params = qt.cast_tree(params, adt)
    report["bytes"] = qt.tree_bytes(params)
    report["ratio"] = bytes0 / max(report["bytes"], 1)
    return cfg, params, report
