"""Attention-head pruning for the deployment config.

The are-16-heads / nn_pruning recipe adapted to the ViTDet backbone:
score each (layer, head) on calibration frames, drop the lowest-K per
layer, and RE-PACK the parameter tree — w_q/w_k/w_v output columns,
their biases, and w_o input rows are physically sliced and ``n_heads``
shrinks in the config, so every downstream executable (the serving
grid, the Pallas window/flash kernels) sees a genuinely narrower q_dim
rather than a masked one.

Head score = mean |head output| on calibration frames (captured by the
eager tap in models.attention) x the Frobenius norm of the head's w_o
rows — the magnitude of what the head actually contributes to the
residual stream.  With no calibration frames the activation term drops
and the w_o norm alone ranks heads (the weight-magnitude proxy).

Exactness property (pinned by tests/test_quant.py): a pruned forward
equals the dense forward with the dropped heads' w_o rows zeroed —
softmax attention is independent per head, so removing a head only
removes its additive w_o contribution.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.config import ModelConfig


def w_o_head_norms(cfg: ModelConfig, params) -> np.ndarray:
    """(n_layers, n_heads) Frobenius norm of each head's w_o rows."""
    H, Dh = cfg.n_heads, cfg.head_dim
    out = []
    for blk in params["blocks"]:
        w_o = np.asarray(blk["attn"]["w_o"], np.float32)   # (H*Dh, D)
        out.append(np.linalg.norm(
            w_o.reshape(H, Dh * w_o.shape[-1]), axis=1))
    return np.stack(out)


def score_heads(cfg: ModelConfig, params, frames: Sequence[np.ndarray],
                ) -> np.ndarray:
    """(n_layers, n_heads) head importance on calibration frames.

    Runs the full-resolution forward EAGERLY (the tap needs concrete
    values) on the XLA backend and multiplies the captured per-head
    mean |output| by the head's w_o row norm.
    """
    from repro.core import vit_backbone as vb
    store: List[np.ndarray] = []
    with attn.head_tap(store):
        for f in frames:
            img = jnp.asarray(np.asarray(f, np.float32))[None]
            vb.forward_features(cfg, params, img, backend="xla")
    acts = np.stack(store).reshape(len(frames), cfg.n_layers,
                                   cfg.n_heads)
    return acts.mean(axis=0) * w_o_head_norms(cfg, params)


def prune_heads(cfg: ModelConfig, params, k: int,
                scores: Optional[np.ndarray] = None):
    """Drop the ``k`` lowest-scoring heads per layer; returns the
    re-packed ``(cfg, params)``.  ``scores``: (n_layers, n_heads),
    default the w_o-norm proxy.  MHA only (ViTDet: H == KV)."""
    if k <= 0:
        return cfg, params, [list(range(cfg.n_heads))] * cfg.n_layers
    assert cfg.n_heads == cfg.n_kv_heads, \
        "head pruning supports MHA only (n_heads == n_kv_heads)"
    H, Dh = cfg.n_heads, cfg.head_dim
    assert 0 < k < H, f"cannot drop {k} of {H} heads"
    if scores is None:
        scores = w_o_head_norms(cfg, params)
    assert scores.shape == (cfg.n_layers, H)

    def slice_cols(w, keep):                      # (D, H*Dh) -> columns
        D = w.shape[0]
        return w.reshape(D, H, Dh)[:, keep].reshape(D, len(keep) * Dh)

    def slice_vec(b, keep):                       # (H*Dh,) bias
        return b.reshape(H, Dh)[keep].reshape(len(keep) * Dh)

    blocks = []
    kept: List[List[int]] = []
    for l, blk in enumerate(params["blocks"]):
        keep = np.sort(np.argsort(scores[l], kind="stable")[k:])
        kept.append([int(i) for i in keep])
        a = dict(blk["attn"])
        for key in ("w_q", "w_k", "w_v"):
            a[key] = slice_cols(a[key], keep)
        for key in ("b_q", "b_k", "b_v"):
            if key in a:
                a[key] = slice_vec(a[key], keep)
        w_o = a["w_o"]                            # (H*Dh, D)
        a["w_o"] = w_o.reshape(H, Dh, w_o.shape[-1])[keep] \
            .reshape(len(keep) * Dh, w_o.shape[-1])
        blocks.append({**blk, "attn": a})
    out = dict(params)
    out["blocks"] = blocks
    cfg2 = cfg.replace(n_heads=H - k, n_kv_heads=H - k)
    return cfg2, out, kept


def zero_heads(cfg: ModelConfig, params, dropped: Sequence[Sequence[int]]):
    """The dense twin of :func:`prune_heads` for parity tests: zero the
    listed heads' w_o rows per layer, leaving shapes unchanged."""
    H, Dh = cfg.n_heads, cfg.head_dim
    blocks = []
    for l, blk in enumerate(params["blocks"]):
        w_o = jnp.asarray(blk["attn"]["w_o"])
        w3 = w_o.reshape(H, Dh, w_o.shape[-1])
        mask = np.ones((H,), np.float32)
        mask[list(dropped[l])] = 0.0
        a = {**blk["attn"], "w_o": (w3 * mask[:, None, None])
             .reshape(w_o.shape)}
        blocks.append({**blk, "attn": a})
    return {**params, "blocks": blocks}
