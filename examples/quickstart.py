"""Quickstart: the paper's C1 — dynamic mixed-resolution inference for a
ViTDet-style dense-prediction model — in ~60 lines of public API.

  PYTHONPATH=src python examples/quickstart.py

Builds the sim-scale ViTDet, packs a synthetic frame into a
mixed-resolution token sequence (object-free regions downsampled 2x),
runs inference at several restoration points (RPs), and prints the
token-count / FLOP savings and detection agreement per RP.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.vitdet_l import SIM
from repro.core import vit_backbone as vb
from repro.core.partition import mask_to_region_ids
from repro.data import synthetic_video as sv
from repro.models import registry
from repro.offload import detection as det
from repro.offload import motion as mo
from repro.offload.simulator import ServerModel


def main() -> int:
    part = vb.vit_partition(SIM)
    print(f"model: {SIM.name}-sim  patch grid {part.grid_h}x{part.grid_w}, "
          f"window {part.window}, downsample {part.downsample} -> "
          f"{part.n_regions} decision regions of r={part.region} patches")

    # use the benchmark-trained weights when the cache exists (run
    # ``python -m benchmarks.run fig8`` once); random init otherwise
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    ckdir = (Path(__file__).resolve().parents[1] / "benchmarks" /
             "artifacts" / "cache" / "server_model")
    try:
        from repro.train import checkpoint as ckpt
        if ckpt.latest_step(str(ckdir)) is not None:
            params = ckpt.restore(params, str(ckdir))
            print("(loaded trained sim weights from the benchmark cache)")
    except Exception:
        pass
    server = ServerModel(SIM, params, score_thresh=0.3)

    frames, gts = sv.make_clip("walkS", 3, size=SIM.vit.img_size[0], seed=1)
    frame, gt = frames[-1], gts[-1]

    # region selection: downsample regions with no objects (paper Fig. 5
    # pilot); rho comes from ground truth here, from the tracker at runtime
    rho = mo.region_density(gt, part, SIM.vit.patch_size)
    mask = (rho == 0).astype(np.int32)
    n_low = int(mask.sum())
    full_tok = part.grid_h * part.grid_w
    mixed_tok = part.n_tokens(n_low)
    print(f"\nframe: {len(gt)} objects; {n_low}/{part.n_regions} regions "
          f"downsampled -> {mixed_tok}/{full_tok} tokens "
          f"({1 - mixed_tok / full_tok:.0%} fewer)")

    # FLOP savings per restoration point, from the FULL ViTDet-L curve
    cfg_l = get_config("vitdet-l")
    f_full = vb.backbone_flops(cfg_l, 0, 0)
    ref = server.infer(frame)
    print(f"\n{'beta':>4} {'backbone FLOPs':>15} {'saved':>6} "
          f"{'agreement F1':>13}")
    for beta in range(SIM.vit.n_subsets + 1):
        f_mix = vb.backbone_flops(cfg_l, n_low, beta)
        dets = server.infer(frame, mask, beta)
        f1 = det.frame_f1(dets, ref)
        print(f"{beta:>4} {f_mix / 1e9:>13.1f}G {1 - f_mix / f_full:>6.0%} "
              f"{f1:>13.3f}")
    print("\nbeta=0 restores at the input (no savings); deeper RPs save "
          "more compute (paper Fig. 5).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
