"""End-to-end training driver: a ~100M-parameter qwen3-family LM trained
for a few hundred steps on the synthetic token pipeline, with sharded
checkpointing and resume.

  PYTHONPATH=src python examples/train_lm_100m.py [--steps 300]
  PYTHONPATH=src python examples/train_lm_100m.py --resume   # restart

The config is the qwen3-4b architecture scaled to ~100M params (same
family: GQA + qk_norm + SwiGLU); loss must fall (the pipeline has a
learnable bigram structure).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import get_config
from repro.launch.train import train


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config("qwen3-4b").replace(
        name="qwen3-100m",
        n_layers=12,
        d_model=640,
        n_heads=10,
        n_kv_heads=2,
        head_dim=64,
        d_ff=2048,
        vocab_size=32768,
        max_seq_len=4096,
    )
    n = cfg.param_count()
    print(f"config: {cfg.name}  params={n/1e6:.0f}M  "
          f"({cfg.n_layers}L d={cfg.d_model} GQA {cfg.n_heads}/"
          f"{cfg.n_kv_heads})")

    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=args.ckpt_dir, resume=args.resume,
                save_every=100, log_every=20)
    improved = out["mean_last10"] < out["first_loss"] - 0.1
    print(f"loss improved: {improved} "
          f"({out['first_loss']:.3f} -> {out['mean_last10']:.3f})")
    return 0 if improved and np.isfinite(out["final_loss"]) else 1


if __name__ == "__main__":
    sys.exit(main())
