"""End-to-end ViTMAlis offloading simulation — the paper's C2 system
(Fig. 6) against the TrackB2B baseline on one synthetic video and one
emulated 4G trace.

  PYTHONPATH=src python examples/offload_simulation.py [--frames 40]

Uses the trained benchmark server model if its cache exists (run
``python -m benchmarks.run fig8`` once to build it); otherwise trains a
quick one (~2 min on CPU).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=40)
    ap.add_argument("--video", default="cycleS")
    ap.add_argument("--trace", default="4g")
    args = ap.parse_args()

    from benchmarks import common as C
    from repro.data.network_traces import make_trace
    from repro.offload.simulator import Simulation

    server = C.get_server()
    part = C.get_part()
    frames, gt = C.video_with_gt(args.video, args.frames)
    trace = make_trace(args.trace, 0, duration_s=args.frames // C.FPS + 60)
    inf_delay = C.paper_delay_model()

    print(f"video={args.video} ({args.frames} frames @ {C.FPS} FPS), "
          f"trace={args.trace} (mean {trace.mean_mbps:.1f} Mbps)\n")
    for policy in C.make_policies():
        if policy.name not in ("TrackB2B", "ViTMAlis"):
            continue
        sim = Simulation(frames, gt, trace, policy, server, part, C.PATCH,
                         fps=C.FPS, inf_delay=inf_delay)
        res = sim.run(video_name=args.video)
        s = res.summary()
        print(f"{policy.name:>10}: rendering_f1={s['median_rendering_f1']:.3f} "
              f"inference_f1={s['mean_inference_f1']:.3f} "
              f"e2e={s['median_e2e_latency']*1e3:.0f}ms "
              f"net={s['median_net_delay']*1e3:.0f}ms "
              f"inf={s['median_inf_delay']*1e3:.0f}ms "
              f"interval={s['median_interval']:.0f} frames")
    print("\nViTMAlis should cut both net and inference delay while "
          "holding rendering accuracy (paper Figs. 8-9).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
