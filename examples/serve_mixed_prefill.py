"""Serving example: wave-batched KV-cache serving with the paper's
technique transposed to sequences — mixed-granularity prefill (pool
low-relevance prompt spans for the first beta backbone subsets, restore
before the rest, decode from a full-resolution cache).

  PYTHONPATH=src python examples/serve_mixed_prefill.py

Runs the same request batch with and without the technique and reports
prefill FLOP savings and output agreement.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import seq_mixed_res as smr
from repro.models import registry
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request

ARCH = "qwen3-4b"
PROMPT_LEN = 256
MAX_NEW = 12
N_REQ = 8


def main() -> int:
    cfg = get_reduced(ARCH)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (PROMPT_LEN,))
               .astype(np.int32) for _ in range(N_REQ)]

    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    n_spans = PROMPT_LEN // span
    span_mask = np.zeros((n_spans,), np.int32)
    span_mask[: n_spans // 2] = 1          # pool the oldest half
    beta = 2

    results = {}
    for name, mask, b in (("full", None, 0), ("mixed", span_mask, beta)):
        engine = ServeEngine(cfg, params, ServeConfig(
            max_batch=N_REQ, max_len=PROMPT_LEN + MAX_NEW + 8,
            buckets=(PROMPT_LEN,)))
        for rid, p in enumerate(prompts):
            engine.submit(Request(rid=rid, prompt=p, max_new_tokens=MAX_NEW,
                                  low_span_mask=mask, beta=b))
        t0 = time.time()
        rs = engine.run()
        results[name] = {r.rid: r.tokens for r in rs}
        print(f"{name:>6}: {len(rs)} requests in {time.time()-t0:.2f}s")

    agree = np.mean([
        np.mean(np.asarray(results["full"][i]) ==
                np.asarray(results["mixed"][i][:len(results['full'][i])]))
        for i in range(N_REQ)])
    n_low = int(span_mask.sum())
    f_full = smr.prefill_flops(cfg, PROMPT_LEN, 0, 0)
    f_mix = smr.prefill_flops(cfg, PROMPT_LEN, n_low, beta)
    print(f"\nprefill FLOPs: {f_full/1e6:.1f}M -> {f_mix/1e6:.1f}M "
          f"({1 - f_mix/f_full:.0%} saved at beta={beta}, "
          f"{n_low}/{n_spans} spans pooled)")
    print(f"token agreement with full prefill: {agree:.0%} "
          f"(untrained weights; trained models retain task accuracy per "
          f"the paper's §III)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
